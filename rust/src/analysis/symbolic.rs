//! Symbolic count-range certification: the lint pass table lifted from
//! single counts to whole count intervals.
//!
//! Per-transfer byte sizes are exact piecewise-affine functions of the
//! element count ([`CountSizer`]), and every registered pass is either
//! *structural* (reads blocks, endpoints, round shape — identical at
//! every count of a fixed structure) or *byte-dependent* (reads
//! `Transfer::bytes` — the deadlock pass). So the full analysis of an
//! algorithm over `[1, max_count]` decomposes finitely:
//!
//! 1. **Structural cells** — counts where the builder emits the same
//!    communication structure. Cacheable algorithms (`cache_id()` is
//!    `Some`) have exactly one; `native`/`tuned` switch structure at
//!    known selection thresholds ([`Persona::native_structure_breaks`],
//!    decision-table breakpoints). Per cell the flow replay and the
//!    structural pass stages run **once**.
//! 2. **Byte cells** — within a structural cell, the only
//!    byte-dependent facts are per-transfer threshold comparisons
//!    (`bytes(c) > limit`). Each transfer crosses each threshold at
//!    most once ([`CountSizer::first_count_above`], exact integer
//!    math), so partitioning at those crossovers makes the deadlock
//!    verdict — and the eager/rendezvous mode split — *constant* on
//!    every cell. One evaluation at the cell floor certifies the whole
//!    interval.
//!
//! Within a cell the certificate's diagnostics are bitwise-identical
//! to a concrete [`super::analyze`] run at any count in it (the
//! differential gate is `certify_crossval.rs`). Evaluation reuses one
//! [`CertArena`] across cells, certificates and registry entries the
//! way `recost_count` reuses the simulator: zero steady-state
//! allocation on clean schedules, counting-allocator-gated by
//! `bench_certify`.

use crate::algorithms::registry::{registry, Alg, AlgError, OpKind};
use crate::harness::plan::fnv1a;
use crate::harness::report::esc;
use crate::model::{Persona, PersonaName};
use crate::schedule::{CountSizer, Schedule, ELEM_BYTES};
use crate::topology::Cluster;

use super::flow::{endpoints_ok, Flow};
use super::passes::{deadlock_with, DeadlockScratch, PassCtx, PREFIX_PASSES, SUFFIX_PASSES};
use super::{codes, truncation_notice, Analysis, DiagSink, Diagnostic, LintConfig, Severity};

/// What to certify against. Distinct from [`LintConfig`] in one way:
/// the *partition* thresholds (where the certificate records the
/// eager→rendezvous mode flip) are separate from the *rendezvous*
/// thresholds (what the deadlock pass judges), so certificates list
/// mode crossovers even when deadlock modelling is off (the default —
/// our exec layer buffers every message).
#[derive(Clone, Copy, Debug)]
pub struct CertifyOptions {
    /// Deadlock-model rendezvous threshold for off-node transfers
    /// (`u64::MAX` = fully buffered, the [`LintConfig`] default).
    pub rendezvous_net: u64,
    /// Same for on-node transfers.
    pub rendezvous_shm: u64,
    /// Per-lint-code diagnostic cap per interval.
    pub max_per_lint: usize,
    /// `(net, shm)` byte thresholds at which the certificate records a
    /// transfer as rendezvous-mode; `None` uses the persona cost
    /// model's eager limits.
    pub partition: Option<(u64, u64)>,
    /// Top of the certified count domain; `None` certifies up to the
    /// u64-safe byte bound ([`CountSizer::max_safe_count`]).
    pub max_count: Option<u64>,
}

impl Default for CertifyOptions {
    fn default() -> Self {
        CertifyOptions {
            rendezvous_net: u64::MAX,
            rendezvous_shm: u64::MAX,
            max_per_lint: 50,
            partition: None,
            max_count: None,
        }
    }
}

/// Reusable evaluation buffers: the resized byte vector, the deadlock
/// pass scratch, and the crossover-cut list. All `clear()`ed (never
/// shrunk) between cells, so a warmed arena certifies clean schedules
/// without allocating.
#[derive(Default)]
pub struct CertArena {
    bytes: Vec<u64>,
    scratch: DeadlockScratch,
    cuts: Vec<u64>,
}

impl CertArena {
    pub fn new() -> CertArena {
        CertArena::default()
    }
}

/// One structural cell's precomputed analysis state: the schedule, its
/// count→bytes function, per-transfer masks, and the structural pass
/// output (flow facts + prefix stage, suffix stage) that holds at
/// *every* count of the structure. Everything byte-dependent is
/// recomputed per byte cell by [`CertShape::eval_cell`].
pub struct CertShape {
    schedule: Schedule,
    cfg: LintConfig,
    sizer: CountSizer,
    /// Per transfer (round-major): crosses nodes.
    offnode: Vec<bool>,
    /// Per transfer: endpoints are sane (in-range, no self-message) —
    /// only these participate in rendezvous facts, matching the
    /// deadlock pass.
    ok: Vec<bool>,
    num_ok: u64,
    /// Flow-replay facts + `PREFIX_PASSES` findings, in emission order.
    prefix: Vec<Diagnostic>,
    prefix_dropped: Vec<(&'static str, usize)>,
    /// `SUFFIX_PASSES` findings.
    suffix: Vec<Diagnostic>,
    suffix_dropped: Vec<(&'static str, usize)>,
}

/// The byte-dependent facts of one count interval, evaluated at its
/// floor (constant across the interval by construction).
pub struct CellOutcome {
    pub rendezvous_transfers: u64,
    pub eager_transfers: u64,
    /// Total off-node bytes at the interval floor / ceiling
    /// (saturating sums — the per-transfer sizes are exact, the
    /// schedule-wide total may clamp at `u64::MAX`).
    pub offnode_bytes_lo: u64,
    pub offnode_bytes_hi: u64,
    /// Deadlock findings (empty on clean cells — no allocation).
    pub deadlock: Vec<Diagnostic>,
    pub deadlock_dropped: usize,
}

impl CertShape {
    /// Run the structural stages once and freeze their output. The
    /// `LintConfig` is captured whole: its port limit parameterizes the
    /// structural port-budget pass, its rendezvous thresholds the
    /// per-cell deadlock pass.
    pub fn build(schedule: Schedule, cfg: &LintConfig) -> CertShape {
        let mut pre = DiagSink::new(cfg.max_per_lint);
        let flow = Flow::run(&schedule, &mut pre);
        let mut suf = DiagSink::new(cfg.max_per_lint);
        {
            let ctx = PassCtx { s: &schedule, cfg, flow: &flow };
            for (_, pass) in PREFIX_PASSES {
                pass(&ctx, &mut pre);
            }
            for (_, pass) in SUFFIX_PASSES {
                pass(&ctx, &mut suf);
            }
        }
        let (prefix, prefix_dropped) = pre.into_parts();
        let (suffix, suffix_dropped) = suf.into_parts();
        let sizer = schedule.count_sizer();
        let n = sizer.num_transfers();
        let mut offnode = Vec::with_capacity(n);
        let mut ok = Vec::with_capacity(n);
        let mut num_ok = 0u64;
        for round in &schedule.rounds {
            for t in &round.transfers {
                offnode.push(!schedule.cluster.same_node(t.src, t.dst));
                let good = endpoints_ok(&schedule, t);
                ok.push(good);
                num_ok += u64::from(good);
            }
        }
        CertShape {
            schedule,
            cfg: *cfg,
            sizer,
            offnode,
            ok,
            num_ok,
            prefix,
            prefix_dropped,
            suffix,
            suffix_dropped,
        }
    }

    /// The schedule structure this shape certifies.
    pub fn structure(&self) -> &'static str {
        self.schedule.algorithm
    }

    pub fn port_limit(&self) -> u32 {
        self.cfg.port_limit
    }

    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Error-severity findings in the structural stages alone (they
    /// recur in every interval's analysis).
    pub fn structural_errors(&self) -> usize {
        self.prefix
            .iter()
            .chain(&self.suffix)
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    /// Largest count with every transfer's byte size still in u64.
    pub fn max_safe_count(&self) -> u64 {
        self.sizer.max_safe_count()
    }

    /// All counts in `(lo, hi]` where some well-formed transfer crosses
    /// one of the `(net, shm)` threshold pairs — the byte-cell
    /// boundaries. Appended deduplicated and sorted into `out` (the
    /// distinct crossover set is tiny: one candidate per distinct
    /// per-transfer slope per threshold).
    fn cuts_into(&self, lo: u64, hi: u64, thresholds: &[(u64, u64)], out: &mut Vec<u64>) {
        out.clear();
        for i in 0..self.sizer.num_transfers() {
            if !self.ok[i] {
                continue;
            }
            for &(net, shm) in thresholds {
                let thr = if self.offnode[i] { net } else { shm };
                if let Some(c) = self.sizer.first_count_above(i, thr, hi) {
                    if c > lo && !out.contains(&c) {
                        out.push(c);
                    }
                }
            }
        }
        out.sort_unstable();
    }

    /// Byte-dependent facts of `[lo, hi]`, evaluated at `lo`. The
    /// caller guarantees no transfer crosses a rendezvous or partition
    /// threshold inside the interval ([`CertShape::eval_cells`] cuts at
    /// exactly those counts), so the deadlock verdict and mode split
    /// hold for every count in it.
    pub fn eval_cell(
        &self,
        lo: u64,
        hi: u64,
        partition: (u64, u64),
        arena: &mut CertArena,
    ) -> CellOutcome {
        let n = self.sizer.num_transfers();
        arena.bytes.resize(n, 0);
        self.sizer.resize_count_into(lo, &mut arena.bytes);
        let mut rendezvous = 0u64;
        let mut off_lo = 0u64;
        for i in 0..n {
            let b = arena.bytes[i];
            if self.offnode[i] {
                off_lo = off_lo.saturating_add(b);
            }
            if self.ok[i] {
                let thr = if self.offnode[i] { partition.0 } else { partition.1 };
                if b > thr {
                    rendezvous += 1;
                }
            }
        }
        let mut sink = DiagSink::new(self.cfg.max_per_lint);
        deadlock_with(&self.schedule, &self.cfg, Some(&arena.bytes), &mut arena.scratch, &mut sink);
        let (deadlock, dropped) = sink.into_parts();
        let deadlock_dropped = dropped.first().map_or(0, |&(_, d)| d);
        let off_hi = if hi == lo {
            off_lo
        } else {
            self.sizer.resize_count_into(hi, &mut arena.bytes);
            let mut sum = 0u64;
            for i in 0..n {
                if self.offnode[i] {
                    sum = sum.saturating_add(arena.bytes[i]);
                }
            }
            sum
        };
        CellOutcome {
            rendezvous_transfers: rendezvous,
            eager_transfers: self.num_ok - rendezvous,
            offnode_bytes_lo: off_lo,
            offnode_bytes_hi: off_hi,
            deadlock,
            deadlock_dropped,
        }
    }

    /// Partition `[lo, hi]` at every threshold crossover (both the
    /// certificate's partition pair and the lint rendezvous pair) and
    /// evaluate each byte cell, invoking `f(cell_lo, cell_hi, facts)`
    /// in ascending order. The shared driver behind [`certify`] and
    /// `bench_certify`'s allocation gate.
    pub fn eval_cells(
        &self,
        lo: u64,
        hi: u64,
        partition: (u64, u64),
        arena: &mut CertArena,
        f: &mut dyn FnMut(u64, u64, CellOutcome),
    ) {
        let thresholds = [partition, (self.cfg.rendezvous_net, self.cfg.rendezvous_shm)];
        let mut cuts = std::mem::take(&mut arena.cuts);
        self.cuts_into(lo, hi, &thresholds, &mut cuts);
        let mut cell_lo = lo;
        for i in 0..=cuts.len() {
            let cell_hi = if i < cuts.len() { cuts[i] - 1 } else { hi };
            let out = self.eval_cell(cell_lo, cell_hi, partition, arena);
            f(cell_lo, cell_hi, out);
            if i < cuts.len() {
                cell_lo = cuts[i];
            }
        }
        arena.cuts = cuts;
    }

    /// Reassemble the full [`Analysis`] for one interval: structural
    /// prefix ++ the cell's deadlock findings ++ structural suffix ++
    /// truncation notices. Notices render through the same
    /// [`truncation_notice`] as [`DiagSink::finish`], in first-drop
    /// order (lint codes are unique per pass and the stages run in
    /// order, so per-stage concatenation *is* chronological order) —
    /// the result is bitwise-identical to [`super::analyze`].
    pub fn assemble(&self, deadlock: &[Diagnostic], deadlock_dropped: usize) -> Analysis {
        let cap = self.cfg.max_per_lint.max(1);
        let extra = self.prefix_dropped.len()
            + usize::from(deadlock_dropped > 0)
            + self.suffix_dropped.len();
        let mut diagnostics =
            Vec::with_capacity(self.prefix.len() + deadlock.len() + self.suffix.len() + extra);
        diagnostics.extend_from_slice(&self.prefix);
        diagnostics.extend_from_slice(deadlock);
        diagnostics.extend_from_slice(&self.suffix);
        for &(code, n) in &self.prefix_dropped {
            diagnostics.push(truncation_notice(code, n, cap));
        }
        if deadlock_dropped > 0 {
            diagnostics.push(truncation_notice(codes::DEADLOCK, deadlock_dropped, cap));
        }
        for &(code, n) in &self.suffix_dropped {
            diagnostics.push(truncation_notice(code, n, cap));
        }
        Analysis { diagnostics }
    }

    /// The exact [`super::analyze`] result for this structure at count
    /// `c`, without rebuilding the schedule or replaying the flow.
    /// Precondition: `c ≤ max_safe_count()`.
    pub fn analysis_at(&self, c: u64, arena: &mut CertArena) -> Analysis {
        let n = self.sizer.num_transfers();
        arena.bytes.resize(n, 0);
        self.sizer.resize_count_into(c, &mut arena.bytes);
        let mut sink = DiagSink::new(self.cfg.max_per_lint);
        deadlock_with(&self.schedule, &self.cfg, Some(&arena.bytes), &mut arena.scratch, &mut sink);
        let (deadlock, dropped) = sink.into_parts();
        self.assemble(&deadlock, dropped.first().map_or(0, |&(_, d)| d))
    }
}

/// Lint one schedule structure at a list of counts through one shared
/// flow replay — the analysis analog of `measure_series`, and the
/// engine behind `mlane lint --counts`. Each returned [`Analysis`] is
/// bitwise-identical to [`super::analyze`] on the schedule resized to
/// that count. Precondition: every count is within the structure's
/// u64-safe domain (the CLI rejects counts past
/// [`CountSizer::max_safe_count`]).
pub fn analyze_series(s: &Schedule, cfg: &LintConfig, counts: &[u64]) -> Vec<Analysis> {
    let shape = CertShape::build(s.clone(), cfg);
    let mut arena = CertArena::default();
    counts.iter().map(|&c| shape.analysis_at(c, &mut arena)).collect()
}

/// One structural cell of a certification: the count range over which
/// the builder emits this exact communication structure.
pub struct CertCell {
    pub lo: u64,
    pub hi: u64,
    pub shape: CertShape,
}

/// Structure-change counts of a non-cacheable algorithm on this
/// (cluster, persona, op): counts `c` where `build(c)` first differs
/// structurally from `build(c - 1)`. Cacheable algorithms promise
/// count-invariant structure via [`Alg::cache_id`]; `native` switches
/// at the persona's selection thresholds; `tuned` at its decision
/// table's breakpoints (plus the native thresholds — native is always
/// a candidate). Over-splitting is sound (two cells with equal
/// structure certify identically), missing a break is not — so any
/// other non-cacheable family is a typed error, never a silent guess.
fn structure_breaks(
    alg: &Alg,
    cl: Cluster,
    persona: &Persona,
    op: OpKind,
) -> Result<Vec<u64>, AlgError> {
    if alg.cache_id().is_some() {
        return Ok(Vec::new());
    }
    match alg.name() {
        "native" => Ok(persona.native_structure_breaks(op)),
        "tuned" => {
            let mut breaks = persona.native_structure_breaks(op);
            let table = crate::tuning::dispatch_table(cl, persona.name, op)?;
            for e in &table.entries {
                if e.from > 1 {
                    breaks.push(e.from);
                }
            }
            breaks.sort_unstable();
            breaks.dedup();
            Ok(breaks)
        }
        other => Err(AlgError::Engine {
            detail: format!(
                "certify: non-cacheable algorithm {other} has no registered structure-break rule"
            ),
        }),
    }
}

/// The port budget in force at count `c` — for `tuned`, the winning
/// candidate's requirement (mirrors the CLI's `port_budget`); constant
/// within a structural cell by construction.
fn port_limit_at(
    alg: &Alg,
    cl: Cluster,
    persona: &Persona,
    op: OpKind,
    c: u64,
) -> Result<u32, AlgError> {
    if alg.name() == "tuned" {
        Ok(crate::tuning::dispatch(cl, persona.name, op, c)?.ports_required(cl, op))
    } else {
        Ok(alg.ports_required(cl, op))
    }
}

/// Partition `[1, max_count]` into structural cells and build each
/// cell's [`CertShape`]. The domain is clipped to the u64-safe byte
/// bound per cell (and to `u64::MAX / ELEM_BYTES` up front for
/// non-cacheable algorithms, whose selection math evaluates
/// `c · ELEM_BYTES` in u64); a cell whose floor already overflows ends
/// the certified domain.
pub fn entry_shapes(
    alg: &Alg,
    cl: Cluster,
    persona: &Persona,
    op: OpKind,
    opts: &CertifyOptions,
) -> Result<Vec<CertCell>, AlgError> {
    let mut hi = opts.max_count.unwrap_or(u64::MAX);
    if alg.cache_id().is_none() {
        hi = hi.min(u64::MAX / ELEM_BYTES);
    }
    if hi == 0 {
        return Ok(Vec::new());
    }
    let mut bounds = vec![1u64];
    for b in structure_breaks(alg, cl, persona, op)? {
        if b > 1 && b <= hi {
            bounds.push(b);
        }
    }
    bounds.sort_unstable();
    bounds.dedup();
    let mut cells = Vec::with_capacity(bounds.len());
    for (i, &lo) in bounds.iter().enumerate() {
        let cell_hi = if i + 1 < bounds.len() { bounds[i + 1] - 1 } else { hi };
        let built = alg.build(cl, persona, op.op(lo))?;
        let ports = port_limit_at(alg, cl, persona, op, lo)?;
        let cfg = LintConfig {
            port_limit: ports,
            rendezvous_net: opts.rendezvous_net,
            rendezvous_shm: opts.rendezvous_shm,
            max_per_lint: opts.max_per_lint,
        };
        let shape = CertShape::build(built.schedule, &cfg);
        let safe = shape.max_safe_count();
        if safe < lo {
            break;
        }
        let clipped = safe < cell_hi;
        cells.push(CertCell { lo, hi: cell_hi.min(safe), shape });
        if clipped {
            break;
        }
    }
    Ok(cells)
}

/// One certified count interval: the structure in force, the byte-mode
/// facts, and the full diagnostic list — valid verbatim at **every**
/// count in `[lo, hi]`.
#[derive(Clone, Debug)]
pub struct CertInterval {
    pub lo: u64,
    /// Inclusive.
    pub hi: u64,
    /// The schedule structure in force ([`Schedule::algorithm`]).
    pub structure: &'static str,
    pub port_limit: u32,
    /// Well-formed transfers above / at-or-below the partition
    /// thresholds (constant across the interval).
    pub rendezvous_transfers: u64,
    pub eager_transfers: u64,
    /// Total off-node bytes at `lo` / `hi` (saturating sums).
    pub offnode_bytes_lo: u64,
    pub offnode_bytes_hi: u64,
    pub analysis: Analysis,
}

/// The certificate for one (algorithm, op, persona, cluster) entry:
/// a gap-free ascending partition of `[1, max_count]` with one
/// [`CertInterval`] per cell.
#[derive(Clone, Debug)]
pub struct Certificate {
    /// Instance label (e.g. "2-ported").
    pub algorithm: String,
    /// Registry family name (e.g. "kported").
    pub family: &'static str,
    pub op: OpKind,
    pub persona: PersonaName,
    pub cluster: Cluster,
    /// Top of the certified domain (clipped at the u64-safe byte
    /// bound; 0 when the domain is empty).
    pub max_count: u64,
    pub intervals: Vec<CertInterval>,
}

impl Certificate {
    pub fn errors(&self) -> usize {
        self.intervals.iter().map(|i| i.analysis.errors()).sum()
    }

    pub fn warnings(&self) -> usize {
        self.intervals.iter().map(|i| i.analysis.warnings()).sum()
    }

    pub fn infos(&self) -> usize {
        self.intervals.iter().map(|i| i.analysis.infos()).sum()
    }

    /// No error-severity finding in any interval.
    pub fn is_clean(&self) -> bool {
        self.intervals.iter().all(|i| i.analysis.is_clean())
    }

    /// The exact counts where behavior changes (each interval's floor
    /// past the first).
    pub fn crossovers(&self) -> Vec<u64> {
        self.intervals.iter().skip(1).map(|i| i.lo).collect()
    }

    /// The interval covering count `c` (intervals are ascending and
    /// gap-free over `[1, max_count]`).
    pub fn interval_for(&self, c: u64) -> Option<&CertInterval> {
        let i = self.intervals.partition_point(|iv| iv.hi < c);
        self.intervals.get(i).filter(|iv| iv.lo <= c && c <= iv.hi)
    }
}

/// Certify one registry algorithm instance for one operation: every
/// count in `[1, max_count]` receives a verdict, in finitely many
/// intervals.
pub fn certify(
    alg: &Alg,
    cl: Cluster,
    persona: &Persona,
    op: OpKind,
    opts: &CertifyOptions,
) -> Result<Certificate, AlgError> {
    certify_into(alg, cl, persona, op, opts, &mut CertArena::default())
}

/// [`certify`] with an explicit arena, for reuse across a registry
/// sweep.
pub fn certify_into(
    alg: &Alg,
    cl: Cluster,
    persona: &Persona,
    op: OpKind,
    opts: &CertifyOptions,
    arena: &mut CertArena,
) -> Result<Certificate, AlgError> {
    let cells = entry_shapes(alg, cl, persona, op, opts)?;
    let partition = opts.partition.unwrap_or((persona.model.eager_net, persona.model.eager_shm));
    let mut intervals = Vec::new();
    for cell in &cells {
        cell.shape.eval_cells(cell.lo, cell.hi, partition, arena, &mut |lo, hi, out| {
            intervals.push(CertInterval {
                lo,
                hi,
                structure: cell.shape.structure(),
                port_limit: cell.shape.port_limit(),
                rendezvous_transfers: out.rendezvous_transfers,
                eager_transfers: out.eager_transfers,
                offnode_bytes_lo: out.offnode_bytes_lo,
                offnode_bytes_hi: out.offnode_bytes_hi,
                analysis: cell.shape.assemble(&out.deadlock, out.deadlock_dropped),
            });
        });
    }
    let max_count = cells.last().map_or(0, |c| c.hi);
    Ok(Certificate {
        algorithm: alg.label(),
        family: alg.name(),
        op,
        persona: persona.name,
        cluster: cl,
        max_count,
        intervals,
    })
}

/// Certify the full validation grid — every registry instance
/// ([`crate::algorithms::registry::Registry::validation_instances`]) ×
/// every supported op in `ops` — reusing one arena throughout.
pub fn certify_registry(
    cl: Cluster,
    persona: &Persona,
    ops: &[OpKind],
    opts: &CertifyOptions,
) -> Result<CertReport, AlgError> {
    let mut arena = CertArena::default();
    let mut certificates = Vec::new();
    for alg in registry().validation_instances(cl) {
        for &op in ops {
            if !alg.supports(op) {
                continue;
            }
            certificates.push(certify_into(&alg, cl, persona, op, opts, &mut arena)?);
        }
    }
    Ok(CertReport::new(cl, persona.name, opts, certificates))
}

/// A full `mlane certify` run: one certificate per (algorithm, op)
/// entry, fingerprinted like shard artifacts so downstream tooling can
/// bind a certificate file to the exact spec that produced it.
#[derive(Clone, Debug)]
pub struct CertReport {
    pub cluster: Cluster,
    pub persona: PersonaName,
    /// FNV-1a over the certification spec (cluster, persona,
    /// thresholds, domain bound, entry list).
    pub fingerprint: u64,
    pub certificates: Vec<Certificate>,
}

impl CertReport {
    pub fn new(
        cluster: Cluster,
        persona: PersonaName,
        opts: &CertifyOptions,
        certificates: Vec<Certificate>,
    ) -> CertReport {
        let mut spec = format!(
            "certify v1|{}x{}x{}|{}|rnet={} rshm={} cap={}|part={:?}|max={:?}",
            cluster.nodes,
            cluster.cores,
            cluster.lanes,
            persona.key(),
            opts.rendezvous_net,
            opts.rendezvous_shm,
            opts.max_per_lint,
            opts.partition,
            opts.max_count,
        );
        for c in &certificates {
            spec.push_str(&format!("|{}:{}", c.algorithm, c.op.name()));
        }
        CertReport { cluster, persona, fingerprint: fnv1a(spec.as_bytes()), certificates }
    }

    pub fn errors(&self) -> usize {
        self.certificates.iter().map(Certificate::errors).sum()
    }

    pub fn warnings(&self) -> usize {
        self.certificates.iter().map(Certificate::warnings).sum()
    }

    pub fn infos(&self) -> usize {
        self.certificates.iter().map(Certificate::infos).sum()
    }

    pub fn intervals(&self) -> usize {
        self.certificates.iter().map(|c| c.intervals.len()).sum()
    }

    /// Text rendering: one header per certificate, one line per
    /// interval, findings listed under intervals that have any, one
    /// summary line at the end.
    pub fn text(&self) -> String {
        let mut out = String::new();
        for c in &self.certificates {
            out.push_str(&format!(
                "== {} {} on {}x{} (lanes={}) [{}]: counts [1, {}] in {} interval(s), {} error(s)\n",
                c.algorithm,
                c.op,
                c.cluster.nodes,
                c.cluster.cores,
                c.cluster.lanes,
                c.persona.key(),
                c.max_count,
                c.intervals.len(),
                c.errors(),
            ));
            for iv in &c.intervals {
                out.push_str(&format!(
                    "  [{}, {}] {} ports={} eager={} rendezvous={}: {} error(s), {} warning(s), {} info(s)\n",
                    iv.lo,
                    iv.hi,
                    iv.structure,
                    iv.port_limit,
                    iv.eager_transfers,
                    iv.rendezvous_transfers,
                    iv.analysis.errors(),
                    iv.analysis.warnings(),
                    iv.analysis.infos(),
                ));
                for d in &iv.analysis.diagnostics {
                    out.push_str("    ");
                    out.push_str(&d.text_line());
                    out.push('\n');
                }
            }
        }
        out.push_str(&format!(
            "certified {} schedule(s) over {} interval(s): {} error(s), {} warning(s), {} info(s) [fingerprint {:016x}]\n",
            self.certificates.len(),
            self.intervals(),
            self.errors(),
            self.warnings(),
            self.infos(),
            self.fingerprint,
        ));
        out
    }

    /// Strict machine-readable JSON (hand-rolled like every artifact in
    /// this crate; the report layer's escaping).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\n  \"fingerprint\": \"{:016x}\",\n  \"nodes\": {},\n  \"cores\": {},\n  \"lanes\": {},\n  \"persona\": \"{}\",\n  \"schedules\": {},\n  \"intervals\": {},\n  \"errors\": {},\n  \"warnings\": {},\n  \"infos\": {},\n  \"certificates\": [",
            self.fingerprint,
            self.cluster.nodes,
            self.cluster.cores,
            self.cluster.lanes,
            self.persona.key(),
            self.certificates.len(),
            self.intervals(),
            self.errors(),
            self.warnings(),
            self.infos(),
        ));
        for (i, c) in self.certificates.iter().enumerate() {
            out.push_str(if i == 0 { "\n    " } else { ",\n    " });
            out.push_str(&format!(
                "{{\"algorithm\":\"{}\",\"family\":\"{}\",\"op\":\"{}\",\"max_count\":{},\"errors\":{},\"warnings\":{},\"infos\":{},\"crossovers\":[",
                esc(&c.algorithm),
                c.family,
                c.op.name(),
                c.max_count,
                c.errors(),
                c.warnings(),
                c.infos(),
            ));
            for (j, x) in c.crossovers().iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&x.to_string());
            }
            out.push_str("],\"intervals\":[");
            for (j, iv) in c.intervals.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{{\"lo\":{},\"hi\":{},\"structure\":\"{}\",\"port_limit\":{},\"eager\":{},\"rendezvous\":{},\"offnode_bytes_lo\":{},\"offnode_bytes_hi\":{},\"errors\":{},\"warnings\":{},\"infos\":{},\"diagnostics\":{}}}",
                    iv.lo,
                    iv.hi,
                    esc(iv.structure),
                    iv.port_limit,
                    iv.eager_transfers,
                    iv.rendezvous_transfers,
                    iv.offnode_bytes_lo,
                    iv.offnode_bytes_hi,
                    iv.analysis.errors(),
                    iv.analysis.warnings(),
                    iv.analysis.infos(),
                    iv.analysis.to_json().replace("\n  ", "").replace('\n', ""),
                ));
            }
            out.push_str("]}");
        }
        if !self.certificates.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::analysis::analyze;
    use crate::schedule::{BlockSet, Collective, Round};

    fn small() -> Cluster {
        Cluster::new(4, 4, 2)
    }

    fn opts_bounded(max: u64) -> CertifyOptions {
        CertifyOptions { max_count: Some(max), ..CertifyOptions::default() }
    }

    /// The differential core, small scale (the full-registry version
    /// lives in tests/certify_crossval.rs): every interval's stored
    /// analysis is bitwise-identical to a concrete analyze() at its
    /// endpoints and an interior sample.
    #[test]
    fn certificate_matches_concrete_analyze() {
        let cl = small();
        let persona = Persona::openmpi();
        let alg = registry().resolve("kported", 2).unwrap();
        for op in [OpKind::Bcast, OpKind::Alltoall] {
            let cert = certify(&alg, cl, &persona, op, &opts_bounded(1 << 20)).unwrap();
            assert!(!cert.intervals.is_empty());
            assert_eq!(cert.intervals[0].lo, 1);
            assert_eq!(cert.max_count, 1 << 20);
            for iv in &cert.intervals {
                for c in [iv.lo, (iv.lo + iv.hi) / 2, iv.hi] {
                    let built = alg.build(cl, &persona, op.op(c)).unwrap();
                    let cfg = LintConfig::new(iv.port_limit);
                    let concrete = analyze(&built.schedule, &cfg);
                    assert_eq!(
                        iv.analysis.to_json(),
                        concrete.to_json(),
                        "{} {op} mismatch at count {c} in [{}, {}]",
                        cert.algorithm,
                        iv.lo,
                        iv.hi
                    );
                }
            }
        }
    }

    /// Intervals tile [1, max_count] with no gaps or overlaps, and
    /// crossovers sit at the persona's eager thresholds for a uniform
    /// single-block-per-transfer op (ring allgather: bytes = 4c).
    #[test]
    fn intervals_tile_the_domain() {
        let cl = small();
        let persona = Persona::openmpi();
        let alg = registry().resolve("ring", 0).unwrap();
        let cert =
            certify(&alg, cl, &persona, OpKind::Allgather, &opts_bounded(1 << 30)).unwrap();
        let mut expect_lo = 1u64;
        for iv in &cert.intervals {
            assert_eq!(iv.lo, expect_lo);
            assert!(iv.hi >= iv.lo);
            expect_lo = iv.hi + 1;
        }
        assert_eq!(expect_lo, cert.max_count + 1);
        // openmpi eager_net 4096: a 1-block transfer flips at c = 1025.
        assert!(
            cert.crossovers().contains(&1025),
            "crossovers {:?} missing eager flip",
            cert.crossovers()
        );
        assert_eq!(cert.interval_for(1024).unwrap().hi, 1024);
        assert_eq!(cert.interval_for(1025).unwrap().lo, 1025);
        assert!(cert.interval_for(cert.max_count + 1).is_none());
    }

    /// A rendezvous exchange cycle is clean below the threshold and an
    /// error-severity deadlock above it, with the flip at the exact
    /// crossover count.
    #[test]
    fn deadlock_flips_at_exact_crossover() {
        // Two single-core nodes exchanging alltoall blocks in one
        // round: a waits-for cycle once both messages turn rendezvous.
        let mut s = Schedule::new(Cluster::new(2, 1, 1), Collective::Alltoall { c: 1 }, "xchg");
        let a = s.transfer(0, 1, BlockSet::single(1));
        let b = s.transfer(1, 0, BlockSet::single(2));
        s.push_round(Round::of(vec![a, b]));
        let cfg = LintConfig::new(1).with_rendezvous(1024, 1024);
        let shape = CertShape::build(s, &cfg);
        let mut arena = CertArena::new();
        let mut cells: Vec<(u64, u64, usize)> = Vec::new();
        shape.eval_cells(1, 1 << 20, (1024, 1024), &mut arena, &mut |lo, hi, out| {
            cells.push((lo, hi, out.deadlock.len()));
        });
        // 4c > 1024 ⇔ c ≥ 257.
        assert_eq!(cells, vec![(1, 256, 0), (257, 1 << 20, 1)]);
        let dirty = shape.analysis_at(257, &mut arena);
        assert_eq!(dirty.errors(), 1);
        assert_eq!(dirty.first_error().unwrap().code, codes::DEADLOCK);
        assert!(shape.analysis_at(256, &mut arena).is_clean());
    }

    /// Truncation notices reassemble in the exact order one combined
    /// sink would emit them, across prefix (flow) and byte (deadlock)
    /// segments, on a deliberately messy schedule.
    #[test]
    fn truncation_reassembly_matches_single_sink() {
        // 2 nodes × 2 cores; bcast root 0. Rounds 1–3 re-deliver block
        // 0 (redundant-transfer drops at cap 1); rounds 2 and 3 each
        // form a 1↔2 off-node rendezvous cycle (second deadlock drops);
        // rank 3 never receives (a delivery error in the prefix).
        let mut s = Schedule::new(
            Cluster::new(2, 2, 1),
            Collective::Bcast { root: 0, c: 8, segments: 1 },
            "messy",
        );
        for _ in 0..2 {
            let a = s.transfer(0, 1, BlockSet::single(0));
            let b = s.transfer(0, 2, BlockSet::single(0));
            s.push_round(Round::of(vec![a, b]));
        }
        for _ in 0..2 {
            let a = s.transfer(1, 2, BlockSet::single(0));
            let b = s.transfer(2, 1, BlockSet::single(0));
            s.push_round(Round::of(vec![a, b]));
        }
        let cfg = LintConfig { max_per_lint: 1, ..LintConfig::new(2).with_rendezvous(16, 16) };
        let shape = CertShape::build(s.clone(), &cfg);
        let mut arena = CertArena::new();
        // c = 8 → 32-byte messages: rendezvous everywhere, both
        // truncation segments active. c = 2 → eager: prefix drops only.
        for c in [2u64, 8] {
            let mut resized = s.clone();
            resized.resize_count(c);
            let concrete = analyze(&resized, &cfg);
            assert_eq!(shape.analysis_at(c, &mut arena).to_json(), concrete.to_json(), "c={c}");
        }
        let dirty = shape.analysis_at(8, &mut arena);
        let trunc: Vec<_> = dirty
            .diagnostics
            .iter()
            .filter(|d| d.code == codes::TRUNCATED)
            .map(|d| d.u64_field("dropped").unwrap())
            .collect();
        assert!(!trunc.is_empty(), "expected truncation notices: {}", dirty.text());
    }

    /// analyze_series output is analyze() at each count, sharing one
    /// replay.
    #[test]
    fn series_matches_pointwise_analyze() {
        let cl = small();
        let persona = Persona::openmpi();
        let alg = registry().resolve("ring", 0).unwrap();
        let built = alg.build(cl, &persona, OpKind::Allgather.op(8)).unwrap();
        let ports = alg.ports_required(cl, OpKind::Allgather);
        let cfg = LintConfig::new(ports).with_rendezvous(4096, 4096);
        let counts = [1u64, 8, 1024, 1025, 65536];
        let series = analyze_series(&built.schedule, &cfg, &counts);
        assert_eq!(series.len(), counts.len());
        for (&c, got) in counts.iter().zip(&series) {
            let mut s = built.schedule.clone();
            s.resize_count(c);
            assert_eq!(got.to_json(), analyze(&s, &cfg).to_json(), "count {c}");
        }
    }

    /// The report fingerprint binds the spec: different thresholds,
    /// different fingerprint.
    #[test]
    fn fingerprint_binds_spec() {
        let cl = small();
        let persona = Persona::openmpi();
        let alg = registry().resolve("ring", 0).unwrap();
        let mk = |opts: &CertifyOptions| {
            let cert = certify(&alg, cl, &persona, OpKind::Allgather, opts).unwrap();
            CertReport::new(cl, persona.name, opts, vec![cert])
        };
        let a = mk(&opts_bounded(1024));
        let b = mk(&opts_bounded(2048));
        assert_ne!(a.fingerprint, b.fingerprint);
        assert_eq!(a.fingerprint, mk(&opts_bounded(1024)).fingerprint);
        // JSON shape sanity; the full parse gate is CI's json.tool.
        let j = a.to_json();
        assert!(j.contains("\"fingerprint\""), "{j}");
        assert!(j.ends_with("]\n}\n"), "{j}");
    }

    /// Warmed arenas evaluate clean cells without allocating — the
    /// property bench_certify gates; checked here with the counting
    /// allocator so a regression fails in `cargo test` too.
    #[test]
    fn eval_is_alloc_free_after_warmup() {
        let cl = small();
        let persona = Persona::openmpi();
        let alg = registry().resolve("kported", 2).unwrap();
        let cells = entry_shapes(&alg, cl, &persona, OpKind::Alltoall, &opts_bounded(1 << 30))
            .unwrap();
        let mut arena = CertArena::new();
        let mut evals = 0usize;
        let mut run = |arena: &mut CertArena| {
            let mut n = 0usize;
            for cell in &cells {
                cell.shape.eval_cells(cell.lo, cell.hi, (4096, 4096), arena, &mut |_, _, out| {
                    assert!(out.deadlock.is_empty());
                    n += 1;
                });
            }
            n
        };
        evals += run(&mut arena); // warmup
        let before = crate::util::allocs::thread_allocations();
        evals += run(&mut arena);
        let allocs = crate::util::allocs::thread_allocations() - before;
        assert!(evals >= 4);
        assert_eq!(allocs, 0, "steady-state certify eval allocated");
    }
}

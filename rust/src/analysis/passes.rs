//! The registered lint passes.
//!
//! Every pass is a plain function over [`PassCtx`] (the schedule, the
//! lint configuration, and the shared [`Flow`] computation) pushing
//! findings into the [`DiagSink`]. To add a pass: write the function,
//! give its output a stable code in [`super::codes`], and append one
//! entry to [`PASSES`] — the driver, CLI, tests and report layer pick
//! it up from there.

use std::collections::{HashMap, HashSet};

use super::flow::{endpoints_ok, Flow, NEVER};
use super::{codes, DiagSink, Diagnostic, LintConfig, Severity};
use crate::algorithms::common::ceil_log;
use crate::schedule::Schedule;

pub(crate) struct PassCtx<'a> {
    pub s: &'a Schedule,
    pub cfg: &'a LintConfig,
    pub flow: &'a Flow,
}

pub(crate) type PassFn = fn(&PassCtx<'_>, &mut DiagSink);

/// Registered lint passes, in emission order, split by what they read.
/// The flow replay itself contributes the per-transfer facts
/// (endpoints, unknown blocks, causality, redundant transfers) before
/// any of these run.
///
/// The split is the symbolic layer's contract ([`super::symbolic`]):
/// `PREFIX_PASSES` and `SUFFIX_PASSES` read only schedule *structure*
/// (blocks, endpoints, round shape, the port limit) — their output is
/// identical at every element count of a fixed structure — while
/// `BYTE_PASSES` read `Transfer::bytes` and must re-evaluate per count
/// interval. A new pass that reads byte sizes **must** go in
/// `BYTE_PASSES`; putting it in a structural stage silently breaks
/// interval certification (`certify_crossval.rs` is the gate).
pub(crate) const PREFIX_PASSES: &[(&str, PassFn)] = &[
    ("delivery", |ctx, sink| delivery(ctx.s, ctx.flow, sink)),
    ("port-budget", |ctx, sink| ports(ctx.s, ctx.cfg.port_limit, false, sink)),
    ("lane-contention", lane_contention),
];

pub(crate) const BYTE_PASSES: &[(&str, PassFn)] = &[("deadlock", |ctx, sink| {
    deadlock_with(ctx.s, ctx.cfg, None, &mut DeadlockScratch::default(), sink)
})];

pub(crate) const SUFFIX_PASSES: &[(&str, PassFn)] = &[
    ("dead-data", dead_data),
    ("round-bound", round_bound),
    ("mergeable-rounds", mergeable_rounds),
];

/// The collective's postcondition: every rank holds its required
/// blocks after the last round.
pub(crate) fn delivery(s: &Schedule, flow: &Flow, sink: &mut DiagSink) {
    let p = s.p();
    for r in 0..p {
        for b in s.op.required_blocks(r, p).iter() {
            if !flow.holds(r as usize, b) {
                sink.push(
                    Diagnostic::new(
                        Severity::Error,
                        codes::DELIVERY,
                        format!("rank {r} missing required block {b} at completion"),
                    )
                    .with("rank", r)
                    .with("block", b),
                );
            }
        }
    }
}

/// The k-ported constraint (§2.1): within a round no rank sources or
/// sinks more than `limit` messages. Counts are full-round totals over
/// well-formed transfers; one diagnostic per (round, rank), anchored at
/// the first transfer that touches the oversubscribed rank.
///
/// `emit_endpoints` re-emits bad-endpoint facts in transfer order —
/// used by the standalone `validate_ports` wrapper, which must
/// reproduce the legacy first-error ordering without running the full
/// flow replay (the driver passes `false`: the flow already emitted
/// them).
pub(crate) fn ports(s: &Schedule, limit: u32, emit_endpoints: bool, sink: &mut DiagSink) {
    let p = s.p() as usize;
    let mut sends = vec![0u32; p];
    let mut recvs = vec![0u32; p];
    let mut reported = vec![false; p];
    let mut flagged: Vec<usize> = Vec::new();
    for (ri, round) in s.rounds.iter().enumerate() {
        for (ti, t) in round.transfers.iter().enumerate() {
            if !endpoints_ok(s, t) {
                if emit_endpoints {
                    sink.push(
                        Diagnostic::new(
                            Severity::Error,
                            codes::BAD_ENDPOINTS,
                            format!("bad endpoints {} -> {}", t.src, t.dst),
                        )
                        .at(ri, ti)
                        .with("src", t.src)
                        .with("dst", t.dst),
                    );
                }
                continue;
            }
            sends[t.src as usize] += 1;
            recvs[t.dst as usize] += 1;
        }
        for (ti, t) in round.transfers.iter().enumerate() {
            if !endpoints_ok(s, t) {
                continue;
            }
            for r in [t.src, t.dst] {
                let (sn, rc) = (sends[r as usize], recvs[r as usize]);
                if (sn > limit || rc > limit) && !reported[r as usize] {
                    reported[r as usize] = true;
                    flagged.push(r as usize);
                    sink.push(
                        Diagnostic::new(
                            Severity::Error,
                            codes::PORT_BUDGET,
                            format!("rank {r} uses {sn} send / {rc} recv ports (limit {limit})"),
                        )
                        .at(ri, ti)
                        .with("rank", r)
                        .with("sends", sn)
                        .with("recvs", rc)
                        .with("limit", limit),
                    );
                }
            }
        }
        for t in &round.transfers {
            if endpoints_ok(s, t) {
                sends[t.src as usize] = 0;
                recvs[t.dst as usize] = 0;
            }
        }
        for r in flagged.drain(..) {
            reported[r] = false;
        }
    }
}

/// The k-lane constraint (§2.2): per round, a node's concurrent
/// off-node sends (and receives) share its `lanes` network lanes. More
/// than `lanes` of either means the backend serializes — warn with the
/// per-round serialization factor, plus one schedule-level summary.
/// Warn, not error: k-lane schedules drive all cores by design and pay
/// for it in the cost model, but the oversubscription is worth seeing.
fn lane_contention(ctx: &PassCtx<'_>, sink: &mut DiagSink) {
    let s = ctx.s;
    let cl = s.cluster;
    let nodes = cl.nodes as usize;
    let mut snd = vec![0u32; nodes];
    let mut rcv = vec![0u32; nodes];
    let mut touched: Vec<usize> = Vec::new();
    let mut max_factor = 1u32;
    let mut contended_rounds = 0u64;
    for (ri, round) in s.rounds.iter().enumerate() {
        for t in &round.transfers {
            if !endpoints_ok(s, t) || cl.same_node(t.src, t.dst) {
                continue;
            }
            let sn = cl.node_of(t.src) as usize;
            let dn = cl.node_of(t.dst) as usize;
            if snd[sn] == 0 && rcv[sn] == 0 {
                touched.push(sn);
            }
            snd[sn] += 1;
            if snd[dn] == 0 && rcv[dn] == 0 {
                touched.push(dn);
            }
            rcv[dn] += 1;
        }
        let mut round_factor = 1u32;
        for &n in &touched {
            let peak = snd[n].max(rcv[n]);
            if peak > cl.lanes {
                let factor = peak.div_ceil(cl.lanes);
                round_factor = round_factor.max(factor);
                sink.push(
                    Diagnostic::new(
                        Severity::Warn,
                        codes::LANE_CONTENTION,
                        format!(
                            "node {n} drives {} off-node sends / {} recvs over {} lane(s): ~{factor}x serialized",
                            snd[n], rcv[n], cl.lanes
                        ),
                    )
                    .at_round(ri)
                    .with("node", n)
                    .with("sends", snd[n])
                    .with("recvs", rcv[n])
                    .with("lanes", cl.lanes)
                    .with("factor", factor),
                );
            }
        }
        if round_factor > 1 {
            contended_rounds += 1;
            max_factor = max_factor.max(round_factor);
        }
        for n in touched.drain(..) {
            snd[n] = 0;
            rcv[n] = 0;
        }
    }
    if max_factor > 1 {
        sink.push(
            Diagnostic::new(
                Severity::Info,
                codes::LANE_SERIALIZATION,
                format!(
                    "{contended_rounds} of {} round(s) oversubscribe the node lanes (worst factor {max_factor})",
                    s.rounds.len()
                ),
            )
            .with("contended_rounds", contended_rounds)
            .with("rounds", s.rounds.len())
            .with("max_factor", max_factor),
        );
    }
}

/// Reusable buffers for [`deadlock_with`]: per-round waits-for edges,
/// the rank index, CSR adjacency, and the Kahn/cycle scratch. All
/// `clear()`ed (never shrunk) between rounds and calls, so a warmed
/// scratch evaluates clean schedules without allocating — the symbolic
/// layer walks one scratch across every count interval of a
/// certification run.
#[derive(Default)]
pub(crate) struct DeadlockScratch {
    edges: Vec<(u32, u32)>,
    ranks: Vec<u32>,
    outdeg: Vec<u32>,
    /// CSR adjacency, filled in edge order (cycle extraction follows
    /// the first unresolved successor, so per-source edge order is part
    /// of the diagnostic's identity).
    succ_off: Vec<u32>,
    succs: Vec<u32>,
    pred_off: Vec<u32>,
    preds: Vec<u32>,
    cursor: Vec<u32>,
    done: Vec<u32>,
    stuck: Vec<u32>,
    on_path: Vec<bool>,
    path: Vec<u32>,
}

/// Rendezvous deadlock: under a synchronous backend, a message above
/// the eager threshold blocks its sender until the receiver posts —
/// and a rank posts its receives only after its own sends complete
/// (the per-round send-then-receive order both backends use). That
/// induces a waits-for edge src → dst per rendezvous transfer; a cycle
/// means no rank in it can ever progress. Our threaded exec layer
/// buffers every message (thresholds default to "never"), so findings
/// here are portability errors against rendezvous MPIs.
///
/// This is the single implementation behind both the concrete pass
/// table and the symbolic certifier: `bytes` overrides every
/// transfer's byte size with a flat round-major slice (the
/// [`crate::schedule::CountSizer`] order) so one schedule structure
/// can be re-judged at any element count without rebuilding it.
/// Keeping one implementation is what makes certificate diagnostics
/// bitwise-identical to `analyze()` output.
// Invariant expects only: every edge endpoint was inserted into
// `ranks`, and Kahn leftovers by construction wait on (and are reached
// from) other leftovers.
#[allow(clippy::expect_used)]
pub(crate) fn deadlock_with(
    s: &Schedule,
    cfg: &LintConfig,
    bytes: Option<&[u64]>,
    scr: &mut DeadlockScratch,
    sink: &mut DiagSink,
) {
    let cl = s.cluster;
    let mut flat = 0usize; // round-major transfer index, matching CountSizer
    for (ri, round) in s.rounds.iter().enumerate() {
        scr.edges.clear();
        for t in &round.transfers {
            let size = match bytes {
                Some(b) => b[flat],
                None => t.bytes,
            };
            flat += 1;
            if !endpoints_ok(s, t) {
                continue;
            }
            let threshold = if cl.same_node(t.src, t.dst) {
                cfg.rendezvous_shm
            } else {
                cfg.rendezvous_net
            };
            if size > threshold {
                scr.edges.push((t.src, t.dst));
            }
        }
        if scr.edges.is_empty() {
            continue;
        }
        scr.ranks.clear();
        scr.ranks.extend(scr.edges.iter().flat_map(|&(a, b)| [a, b]));
        scr.ranks.sort_unstable();
        scr.ranks.dedup();
        let ranks = &scr.ranks;
        let idx =
            |r: u32| ranks.binary_search(&r).expect("endpoint is in the rank list") as u32;
        let n = ranks.len();
        scr.outdeg.clear();
        scr.outdeg.resize(n, 0);
        scr.succ_off.clear();
        scr.succ_off.resize(n + 1, 0);
        scr.pred_off.clear();
        scr.pred_off.resize(n + 1, 0);
        for &(a, b) in &scr.edges {
            let (ai, bi) = (idx(a), idx(b));
            scr.outdeg[ai as usize] += 1;
            scr.succ_off[ai as usize + 1] += 1;
            scr.pred_off[bi as usize + 1] += 1;
        }
        for i in 0..n {
            scr.succ_off[i + 1] += scr.succ_off[i];
            scr.pred_off[i + 1] += scr.pred_off[i];
        }
        let m = scr.edges.len();
        scr.succs.clear();
        scr.succs.resize(m, 0);
        scr.preds.clear();
        scr.preds.resize(m, 0);
        scr.cursor.clear();
        scr.cursor.extend_from_slice(&scr.succ_off[..n]);
        for ei in 0..m {
            let (a, b) = scr.edges[ei];
            let ai = idx(a);
            let slot = scr.cursor[ai as usize];
            scr.succs[slot as usize] = idx(b);
            scr.cursor[ai as usize] = slot + 1;
        }
        scr.cursor.clear();
        scr.cursor.extend_from_slice(&scr.pred_off[..n]);
        for ei in 0..m {
            let (a, b) = scr.edges[ei];
            let bi = idx(b);
            let slot = scr.cursor[bi as usize];
            scr.preds[slot as usize] = idx(a);
            scr.cursor[bi as usize] = slot + 1;
        }
        // A rank with no pending rendezvous send completes its round;
        // completing resolves every edge pointing at it. Fixpoint =
        // Kahn's algorithm on the waits-for graph; leftovers wait
        // forever.
        scr.done.clear();
        scr.done.extend((0..n as u32).filter(|&i| scr.outdeg[i as usize] == 0));
        let mut head = 0;
        while head < scr.done.len() {
            let i = scr.done[head] as usize;
            head += 1;
            for pi in scr.pred_off[i]..scr.pred_off[i + 1] {
                let a = scr.preds[pi as usize] as usize;
                scr.outdeg[a] -= 1;
                if scr.outdeg[a] == 0 {
                    scr.done.push(a as u32);
                }
            }
        }
        scr.stuck.clear();
        scr.stuck.extend((0..n as u32).filter(|&i| scr.outdeg[i as usize] > 0));
        if scr.stuck.is_empty() {
            continue;
        }
        // Extract one concrete cycle: from any stuck rank, follow
        // unresolved edges (which stay within the stuck set) until a
        // rank repeats.
        scr.on_path.clear();
        scr.on_path.resize(n, false);
        scr.path.clear();
        let mut cur = scr.stuck[0];
        let cycle_start = loop {
            if scr.on_path[cur as usize] {
                break scr
                    .path
                    .iter()
                    .position(|&x| x == cur)
                    .expect("repeat is on the path");
            }
            scr.on_path[cur as usize] = true;
            scr.path.push(cur);
            let i = cur as usize;
            cur = (scr.succ_off[i]..scr.succ_off[i + 1])
                .map(|si| scr.succs[si as usize])
                .find(|&j| scr.outdeg[j as usize] > 0)
                .expect("a stuck rank waits on a stuck rank");
        };
        let cycle = &scr.path[cycle_start..];
        let mut desc = String::new();
        for &i in cycle {
            desc.push_str(&format!("{} -> ", ranks[i as usize]));
        }
        desc.push_str(&ranks[cycle[0] as usize].to_string());
        sink.push(
            Diagnostic::new(
                Severity::Error,
                codes::DEADLOCK,
                format!("{} rank(s) wait in a rendezvous cycle: {desc}", scr.stuck.len()),
            )
            .at_round(ri)
            .with("ranks", scr.stuck.len())
            .with("cycle_len", cycle.len()),
        );
    }
    if let Some(b) = bytes {
        debug_assert_eq!(flat, b.len(), "bytes override must cover every transfer");
    }
}

/// Dead data: blocks a rank received but neither requires nor ever
/// forwards afterwards — wasted bandwidth the flow tables expose
/// directly (first-receive vs. last-held-send round per domain block).
fn dead_data(ctx: &PassCtx<'_>, sink: &mut DiagSink) {
    let s = ctx.s;
    let p = s.p();
    for r in 0..p as usize {
        let required = s.op.required_blocks(r as u32, p);
        let mut count = 0u64;
        let mut sample = None;
        for (i, &b) in ctx.flow.domain[r].iter().enumerate() {
            let fr = ctx.flow.first_recv[r][i];
            if fr == NEVER || required.contains(b) {
                continue;
            }
            let ls = ctx.flow.last_send[r][i];
            if ls != NEVER && ls > fr {
                continue; // forwarded after arrival
            }
            count += 1;
            if sample.is_none() {
                sample = Some(b);
            }
        }
        if let Some(b) = sample {
            sink.push(
                Diagnostic::new(
                    Severity::Warn,
                    codes::DEAD_DATA,
                    format!(
                        "rank {r} receives {count} block(s) it neither requires nor forwards (e.g. block {b})"
                    ),
                )
                .with("rank", r)
                .with("count", count)
                .with("block", b),
            );
        }
    }
}

/// Round optimality (§2): any k-ported collective needs at least
/// ceil(log_{k+1} p) rounds to even reach every rank. Slack over the
/// bound is informational — latency-lean algorithms (round-robin
/// alltoall, linear scatter) trade rounds for bandwidth on purpose.
fn round_bound(ctx: &PassCtx<'_>, sink: &mut DiagSink) {
    let s = ctx.s;
    let p = s.p();
    if p <= 1 || s.rounds.is_empty() || ctx.cfg.port_limit == 0 {
        return;
    }
    let lower = ceil_log(p, ctx.cfg.port_limit + 1) as usize;
    let rounds = s.rounds.len();
    if rounds > lower {
        sink.push(
            Diagnostic::new(
                Severity::Info,
                codes::ROUND_BOUND,
                format!(
                    "{rounds} round(s); the {}-ported lower bound is {lower} (slack {})",
                    ctx.cfg.port_limit,
                    rounds - lower
                ),
            )
            .with("rounds", rounds)
            .with("lower", lower)
            .with("slack", rounds - lower),
        );
    }
}

/// Adjacent rounds that could be one round: no data dependency (round
/// r+1 sends nothing that arrived in round r), no shared (src, dst)
/// pair, and the merged per-rank send/recv counts still fit the port
/// budget. Node-phase rounds are structural (backends special-case
/// them) and never merge candidates.
fn mergeable_rounds(ctx: &PassCtx<'_>, sink: &mut DiagSink) {
    let s = ctx.s;
    let limit = ctx.cfg.port_limit;
    for ri in 0..s.rounds.len().saturating_sub(1) {
        let (a, b) = (&s.rounds[ri], &s.rounds[ri + 1]);
        if a.node_phase.is_some() || b.node_phase.is_some() {
            continue;
        }
        let pairs: HashSet<(u32, u32)> = a.transfers.iter().map(|t| (t.src, t.dst)).collect();
        if b.transfers.iter().any(|t| pairs.contains(&(t.src, t.dst))) {
            continue;
        }
        let mut ports: HashMap<u32, (u32, u32)> = HashMap::new();
        for t in a.transfers.iter().chain(&b.transfers) {
            ports.entry(t.src).or_default().0 += 1;
            ports.entry(t.dst).or_default().1 += 1;
        }
        if ports.values().any(|&(sn, rc)| sn > limit || rc > limit) {
            continue;
        }
        let received: HashSet<(u32, u64)> = a
            .transfers
            .iter()
            .flat_map(|t| t.blocks.iter().map(move |bl| (t.dst, bl)))
            .collect();
        if b.transfers.iter().any(|t| t.blocks.iter().any(|bl| received.contains(&(t.src, bl)))) {
            continue;
        }
        sink.push(
            Diagnostic::new(
                Severity::Info,
                codes::MERGEABLE_ROUNDS,
                format!(
                    "rounds {ri} and {} are independent and fit the port budget merged",
                    ri + 1
                ),
            )
            .at_round(ri)
            .with("round", ri)
            .with("next", ri + 1),
        );
    }
}

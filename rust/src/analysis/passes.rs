//! The registered lint passes.
//!
//! Every pass is a plain function over [`PassCtx`] (the schedule, the
//! lint configuration, and the shared [`Flow`] computation) pushing
//! findings into the [`DiagSink`]. To add a pass: write the function,
//! give its output a stable code in [`super::codes`], and append one
//! entry to [`PASSES`] — the driver, CLI, tests and report layer pick
//! it up from there.

use std::collections::{HashMap, HashSet};

use super::flow::{endpoints_ok, Flow, NEVER};
use super::{codes, DiagSink, Diagnostic, LintConfig, Severity};
use crate::algorithms::common::ceil_log;
use crate::schedule::Schedule;

pub(crate) struct PassCtx<'a> {
    pub s: &'a Schedule,
    pub cfg: &'a LintConfig,
    pub flow: &'a Flow,
}

pub(crate) type PassFn = fn(&PassCtx<'_>, &mut DiagSink);

/// Registered lint passes, in emission order. The flow replay itself
/// contributes the per-transfer facts (endpoints, unknown blocks,
/// causality, redundant transfers) before any of these run.
pub(crate) const PASSES: &[(&str, PassFn)] = &[
    ("delivery", |ctx, sink| delivery(ctx.s, ctx.flow, sink)),
    ("port-budget", |ctx, sink| ports(ctx.s, ctx.cfg.port_limit, false, sink)),
    ("lane-contention", lane_contention),
    ("deadlock", deadlock),
    ("dead-data", dead_data),
    ("round-bound", round_bound),
    ("mergeable-rounds", mergeable_rounds),
];

/// The collective's postcondition: every rank holds its required
/// blocks after the last round.
pub(crate) fn delivery(s: &Schedule, flow: &Flow, sink: &mut DiagSink) {
    let p = s.p();
    for r in 0..p {
        for b in s.op.required_blocks(r, p).iter() {
            if !flow.holds(r as usize, b) {
                sink.push(
                    Diagnostic::new(
                        Severity::Error,
                        codes::DELIVERY,
                        format!("rank {r} missing required block {b} at completion"),
                    )
                    .with("rank", r)
                    .with("block", b),
                );
            }
        }
    }
}

/// The k-ported constraint (§2.1): within a round no rank sources or
/// sinks more than `limit` messages. Counts are full-round totals over
/// well-formed transfers; one diagnostic per (round, rank), anchored at
/// the first transfer that touches the oversubscribed rank.
///
/// `emit_endpoints` re-emits bad-endpoint facts in transfer order —
/// used by the standalone `validate_ports` wrapper, which must
/// reproduce the legacy first-error ordering without running the full
/// flow replay (the driver passes `false`: the flow already emitted
/// them).
pub(crate) fn ports(s: &Schedule, limit: u32, emit_endpoints: bool, sink: &mut DiagSink) {
    let p = s.p() as usize;
    let mut sends = vec![0u32; p];
    let mut recvs = vec![0u32; p];
    let mut reported = vec![false; p];
    let mut flagged: Vec<usize> = Vec::new();
    for (ri, round) in s.rounds.iter().enumerate() {
        for (ti, t) in round.transfers.iter().enumerate() {
            if !endpoints_ok(s, t) {
                if emit_endpoints {
                    sink.push(
                        Diagnostic::new(
                            Severity::Error,
                            codes::BAD_ENDPOINTS,
                            format!("bad endpoints {} -> {}", t.src, t.dst),
                        )
                        .at(ri, ti)
                        .with("src", t.src)
                        .with("dst", t.dst),
                    );
                }
                continue;
            }
            sends[t.src as usize] += 1;
            recvs[t.dst as usize] += 1;
        }
        for (ti, t) in round.transfers.iter().enumerate() {
            if !endpoints_ok(s, t) {
                continue;
            }
            for r in [t.src, t.dst] {
                let (sn, rc) = (sends[r as usize], recvs[r as usize]);
                if (sn > limit || rc > limit) && !reported[r as usize] {
                    reported[r as usize] = true;
                    flagged.push(r as usize);
                    sink.push(
                        Diagnostic::new(
                            Severity::Error,
                            codes::PORT_BUDGET,
                            format!("rank {r} uses {sn} send / {rc} recv ports (limit {limit})"),
                        )
                        .at(ri, ti)
                        .with("rank", r)
                        .with("sends", sn)
                        .with("recvs", rc)
                        .with("limit", limit),
                    );
                }
            }
        }
        for t in &round.transfers {
            if endpoints_ok(s, t) {
                sends[t.src as usize] = 0;
                recvs[t.dst as usize] = 0;
            }
        }
        for r in flagged.drain(..) {
            reported[r] = false;
        }
    }
}

/// The k-lane constraint (§2.2): per round, a node's concurrent
/// off-node sends (and receives) share its `lanes` network lanes. More
/// than `lanes` of either means the backend serializes — warn with the
/// per-round serialization factor, plus one schedule-level summary.
/// Warn, not error: k-lane schedules drive all cores by design and pay
/// for it in the cost model, but the oversubscription is worth seeing.
fn lane_contention(ctx: &PassCtx<'_>, sink: &mut DiagSink) {
    let s = ctx.s;
    let cl = s.cluster;
    let nodes = cl.nodes as usize;
    let mut snd = vec![0u32; nodes];
    let mut rcv = vec![0u32; nodes];
    let mut touched: Vec<usize> = Vec::new();
    let mut max_factor = 1u32;
    let mut contended_rounds = 0u64;
    for (ri, round) in s.rounds.iter().enumerate() {
        for t in &round.transfers {
            if !endpoints_ok(s, t) || cl.same_node(t.src, t.dst) {
                continue;
            }
            let sn = cl.node_of(t.src) as usize;
            let dn = cl.node_of(t.dst) as usize;
            if snd[sn] == 0 && rcv[sn] == 0 {
                touched.push(sn);
            }
            snd[sn] += 1;
            if snd[dn] == 0 && rcv[dn] == 0 {
                touched.push(dn);
            }
            rcv[dn] += 1;
        }
        let mut round_factor = 1u32;
        for &n in &touched {
            let peak = snd[n].max(rcv[n]);
            if peak > cl.lanes {
                let factor = peak.div_ceil(cl.lanes);
                round_factor = round_factor.max(factor);
                sink.push(
                    Diagnostic::new(
                        Severity::Warn,
                        codes::LANE_CONTENTION,
                        format!(
                            "node {n} drives {} off-node sends / {} recvs over {} lane(s): ~{factor}x serialized",
                            snd[n], rcv[n], cl.lanes
                        ),
                    )
                    .at_round(ri)
                    .with("node", n)
                    .with("sends", snd[n])
                    .with("recvs", rcv[n])
                    .with("lanes", cl.lanes)
                    .with("factor", factor),
                );
            }
        }
        if round_factor > 1 {
            contended_rounds += 1;
            max_factor = max_factor.max(round_factor);
        }
        for n in touched.drain(..) {
            snd[n] = 0;
            rcv[n] = 0;
        }
    }
    if max_factor > 1 {
        sink.push(
            Diagnostic::new(
                Severity::Info,
                codes::LANE_SERIALIZATION,
                format!(
                    "{contended_rounds} of {} round(s) oversubscribe the node lanes (worst factor {max_factor})",
                    s.rounds.len()
                ),
            )
            .with("contended_rounds", contended_rounds)
            .with("rounds", s.rounds.len())
            .with("max_factor", max_factor),
        );
    }
}

/// Rendezvous deadlock: under a synchronous backend, a message above
/// the eager threshold blocks its sender until the receiver posts —
/// and a rank posts its receives only after its own sends complete
/// (the per-round send-then-receive order both backends use). That
/// induces a waits-for edge src → dst per rendezvous transfer; a cycle
/// means no rank in it can ever progress. Our threaded exec layer
/// buffers every message (thresholds default to "never"), so findings
/// here are portability errors against rendezvous MPIs.
fn deadlock(ctx: &PassCtx<'_>, sink: &mut DiagSink) {
    let s = ctx.s;
    let cl = s.cluster;
    for (ri, round) in s.rounds.iter().enumerate() {
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for t in &round.transfers {
            if !endpoints_ok(s, t) {
                continue;
            }
            let threshold = if cl.same_node(t.src, t.dst) {
                ctx.cfg.rendezvous_shm
            } else {
                ctx.cfg.rendezvous_net
            };
            if t.bytes > threshold {
                edges.push((t.src, t.dst));
            }
        }
        if edges.is_empty() {
            continue;
        }
        let mut ranks: Vec<u32> = edges.iter().flat_map(|&(a, b)| [a, b]).collect();
        ranks.sort_unstable();
        ranks.dedup();
        let idx = |r: u32| ranks.binary_search(&r).expect("endpoint is in the rank list");
        let n = ranks.len();
        let mut outdeg = vec![0u32; n];
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        for &(a, b) in &edges {
            let (ai, bi) = (idx(a), idx(b));
            outdeg[ai] += 1;
            preds[bi].push(ai);
            succs[ai].push(bi);
        }
        // A rank with no pending rendezvous send completes its round;
        // completing resolves every edge pointing at it. Fixpoint =
        // Kahn's algorithm on the waits-for graph; leftovers wait
        // forever.
        let mut done: Vec<usize> = (0..n).filter(|&i| outdeg[i] == 0).collect();
        let mut head = 0;
        while head < done.len() {
            let i = done[head];
            head += 1;
            for &a in &preds[i] {
                outdeg[a] -= 1;
                if outdeg[a] == 0 {
                    done.push(a);
                }
            }
        }
        let stuck: Vec<usize> = (0..n).filter(|&i| outdeg[i] > 0).collect();
        if stuck.is_empty() {
            continue;
        }
        // Extract one concrete cycle: from any stuck rank, follow
        // unresolved edges (which stay within the stuck set) until a
        // rank repeats.
        let mut on_path = vec![false; n];
        let mut path: Vec<usize> = Vec::new();
        let mut cur = stuck[0];
        let cycle: Vec<u32> = loop {
            if on_path[cur] {
                let start = path.iter().position(|&x| x == cur).expect("repeat is on the path");
                break path[start..].iter().map(|&i| ranks[i]).collect();
            }
            on_path[cur] = true;
            path.push(cur);
            cur = *succs[cur]
                .iter()
                .find(|&&j| outdeg[j] > 0)
                .expect("a stuck rank waits on a stuck rank");
        };
        let mut desc = String::new();
        for r in &cycle {
            desc.push_str(&format!("{r} -> "));
        }
        desc.push_str(&cycle[0].to_string());
        sink.push(
            Diagnostic::new(
                Severity::Error,
                codes::DEADLOCK,
                format!("{} rank(s) wait in a rendezvous cycle: {desc}", stuck.len()),
            )
            .at_round(ri)
            .with("ranks", stuck.len())
            .with("cycle_len", cycle.len()),
        );
    }
}

/// Dead data: blocks a rank received but neither requires nor ever
/// forwards afterwards — wasted bandwidth the flow tables expose
/// directly (first-receive vs. last-held-send round per domain block).
fn dead_data(ctx: &PassCtx<'_>, sink: &mut DiagSink) {
    let s = ctx.s;
    let p = s.p();
    for r in 0..p as usize {
        let required = s.op.required_blocks(r as u32, p);
        let mut count = 0u64;
        let mut sample = None;
        for (i, &b) in ctx.flow.domain[r].iter().enumerate() {
            let fr = ctx.flow.first_recv[r][i];
            if fr == NEVER || required.contains(b) {
                continue;
            }
            let ls = ctx.flow.last_send[r][i];
            if ls != NEVER && ls > fr {
                continue; // forwarded after arrival
            }
            count += 1;
            if sample.is_none() {
                sample = Some(b);
            }
        }
        if let Some(b) = sample {
            sink.push(
                Diagnostic::new(
                    Severity::Warn,
                    codes::DEAD_DATA,
                    format!(
                        "rank {r} receives {count} block(s) it neither requires nor forwards (e.g. block {b})"
                    ),
                )
                .with("rank", r)
                .with("count", count)
                .with("block", b),
            );
        }
    }
}

/// Round optimality (§2): any k-ported collective needs at least
/// ceil(log_{k+1} p) rounds to even reach every rank. Slack over the
/// bound is informational — latency-lean algorithms (round-robin
/// alltoall, linear scatter) trade rounds for bandwidth on purpose.
fn round_bound(ctx: &PassCtx<'_>, sink: &mut DiagSink) {
    let s = ctx.s;
    let p = s.p();
    if p <= 1 || s.rounds.is_empty() || ctx.cfg.port_limit == 0 {
        return;
    }
    let lower = ceil_log(p, ctx.cfg.port_limit + 1) as usize;
    let rounds = s.rounds.len();
    if rounds > lower {
        sink.push(
            Diagnostic::new(
                Severity::Info,
                codes::ROUND_BOUND,
                format!(
                    "{rounds} round(s); the {}-ported lower bound is {lower} (slack {})",
                    ctx.cfg.port_limit,
                    rounds - lower
                ),
            )
            .with("rounds", rounds)
            .with("lower", lower)
            .with("slack", rounds - lower),
        );
    }
}

/// Adjacent rounds that could be one round: no data dependency (round
/// r+1 sends nothing that arrived in round r), no shared (src, dst)
/// pair, and the merged per-rank send/recv counts still fit the port
/// budget. Node-phase rounds are structural (backends special-case
/// them) and never merge candidates.
fn mergeable_rounds(ctx: &PassCtx<'_>, sink: &mut DiagSink) {
    let s = ctx.s;
    let limit = ctx.cfg.port_limit;
    for ri in 0..s.rounds.len().saturating_sub(1) {
        let (a, b) = (&s.rounds[ri], &s.rounds[ri + 1]);
        if a.node_phase.is_some() || b.node_phase.is_some() {
            continue;
        }
        let pairs: HashSet<(u32, u32)> = a.transfers.iter().map(|t| (t.src, t.dst)).collect();
        if b.transfers.iter().any(|t| pairs.contains(&(t.src, t.dst))) {
            continue;
        }
        let mut ports: HashMap<u32, (u32, u32)> = HashMap::new();
        for t in a.transfers.iter().chain(&b.transfers) {
            ports.entry(t.src).or_default().0 += 1;
            ports.entry(t.dst).or_default().1 += 1;
        }
        if ports.values().any(|&(sn, rc)| sn > limit || rc > limit) {
            continue;
        }
        let received: HashSet<(u32, u64)> = a
            .transfers
            .iter()
            .flat_map(|t| t.blocks.iter().map(move |bl| (t.dst, bl)))
            .collect();
        if b.transfers.iter().any(|t| t.blocks.iter().any(|bl| received.contains(&(t.src, bl)))) {
            continue;
        }
        sink.push(
            Diagnostic::new(
                Severity::Info,
                codes::MERGEABLE_ROUNDS,
                format!(
                    "rounds {ri} and {} are independent and fit the port budget merged",
                    ri + 1
                ),
            )
            .at_round(ri)
            .with("round", ri)
            .with("next", ri + 1),
        );
    }
}

//! `mlane serve` — the algorithm-selection service.
//!
//! PR 4's decision tables made per-size selection a batch artifact;
//! this module makes it a product. A [`Service`] loads a
//! [`TuningBook`], compiles it into an immutable [`Snapshot`] — tables
//! keyed by (cluster, op, persona) in one flat sorted key array,
//! breakpoints as a flat `from` array searched branchlessly, and the
//! *complete response text precomputed per breakpoint* — and answers
//! newline-delimited JSON queries over stdin/stdout or a Unix socket.
//!
//! Protocol (one object per line, strict subset of JSON — see
//! [`wire`]):
//!
//! ```text
//! → {"op":"bcast","persona":"openmpi","nodes":36,"cores":32,"lanes":2,"count":1000}
//! ← {"ok":true,"op":"bcast","persona":"openmpi","alg":"klane","k":2,"label":"2-lane","from":600,"avg_us":12.5}
//! → {"batch":[<query>,...]}
//! ← {"ok":true,"answers":[<answer>,...]}
//! → {"cmd":"reload"} | {"cmd":"stats"} | {"cmd":"quit"}
//! ← {"ok":false,"error":"..."}        (any malformed line; never an exit)
//! ```
//!
//! Invariants:
//!
//! - **Zero-alloc hot path.** A well-formed covered query on a warm
//!   buffer performs no allocation: wire scan borrows from the line,
//!   the lookup is two binary searches, and the answer is a `push_str`
//!   of precomputed text (`rust/tests/serve_alloc.rs` enforces this
//!   with the counting allocator; `benches/engine_perf.rs` records
//!   `serve_steady_allocs`, gated to 0 in CI).
//! - **Torn-free reload.** A new snapshot is fully compiled off to the
//!   side, then swapped behind the `RwLock` in one assignment; every
//!   response (and every *batch*) is served from exactly one snapshot.
//!   On any reload error the old snapshot stays installed.
//! - **Registry work at load time.** Every breakpoint winner is
//!   resolved against the registry when the snapshot is compiled —
//!   the query path never touches the registry or the book.

// The service must answer malformed input with an error line, never a
// panic: no unwrap/expect anywhere in serve (lock poisoning is handled
// by into_inner — the snapshot swap is a single assignment and cannot
// tear).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod wire;

use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::algorithms::registry::registry;
use crate::harness::report::esc;
use crate::tuning::{TuneError, TuningBook};
use self::wire::{Cmd, Query};

/// Typed serve-layer failures. Request-shaped problems become error
/// *responses* (the daemon never exits on bad input); book-shaped
/// problems fail `load`/`reload` and keep the old snapshot.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// A request line failed the strict wire scan or named a scenario
    /// the snapshot does not cover.
    Request(String),
    /// The backing book failed to load, validate, or compile.
    Book(TuneError),
    /// Reading requests or writing responses failed.
    Io(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Request(msg) => write!(f, "bad request: {msg}"),
            ServeError::Book(e) => write!(f, "serve book: {e}"),
            ServeError::Io(msg) => write!(f, "serve io: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::Book(e) => Some(e),
            _ => None,
        }
    }
}

/// Lookup key: cluster dims plus dense op/persona discriminants.
/// Tuples of integers are `Ord`, so keys sort and binary-search
/// directly (`OpKind`/`PersonaName` themselves are not `Ord`).
type SlotKey = (u32, u32, u32, u8, u8);

fn slot_key(q: &Query) -> SlotKey {
    (q.nodes, q.cores, q.lanes, q.op as u8, q.persona as u8)
}

/// One decision table compiled for serving: breakpoints as a flat
/// sorted `from` array plus the complete response text per breakpoint.
struct CompiledTable {
    froms: Vec<u64>,
    /// Full single-query response line per breakpoint (trailing `\n`).
    lines: Vec<String>,
    /// The same object as a batch-array element (no newline).
    items: Vec<String>,
}

impl CompiledTable {
    /// Index of the breakpoint governing count `c`: the last `from <=
    /// c`, saturating to 0 below the first breakpoint and open-ended
    /// past the last — the same total semantics as
    /// `DecisionTable::pick`, as a branchless halving search (the
    /// select compiles to a conditional move, not a branch).
    #[inline]
    fn pick_idx(&self, c: u64) -> usize {
        let froms = &self.froms;
        let mut base = 0usize;
        let mut size = froms.len();
        while size > 1 {
            let half = size / 2;
            let mid = base + half;
            base = if froms[mid] <= c { mid } else { base };
            size -= half;
        }
        base
    }
}

/// An immutable compiled view of one [`TuningBook`]. Built off to the
/// side and swapped in atomically behind an `Arc`, so readers see the
/// old snapshot or the new one, never a mix.
pub struct Snapshot {
    keys: Vec<SlotKey>,
    tables: Vec<CompiledTable>,
    generation: u64,
}

impl Snapshot {
    /// Validate and compile `book`. Winner resolution (and therefore
    /// every possible registry error) happens here, once per reload.
    pub fn compile(book: &TuningBook, generation: u64) -> Result<Snapshot, ServeError> {
        book.validate().map_err(ServeError::Book)?;
        let mut pairs: Vec<(SlotKey, CompiledTable)> = Vec::with_capacity(book.tables.len());
        for t in &book.tables {
            let key = (
                t.cluster.nodes,
                t.cluster.cores,
                t.cluster.lanes,
                t.op as u8,
                t.persona as u8,
            );
            let mut froms = Vec::with_capacity(t.entries.len());
            let mut lines = Vec::with_capacity(t.entries.len());
            let mut items = Vec::with_capacity(t.entries.len());
            for b in &t.entries {
                // `validate` already resolved every entry; resolving
                // again keeps the error typed if the registry and the
                // book ever disagree, and yields the display label.
                let alg = registry().resolve(&b.alg, b.k).map_err(|e| {
                    ServeError::Book(TuneError::Parse(format!("{}: {e}", t.label())))
                })?;
                let item = format!(
                    "{{\"ok\":true,\"op\":\"{}\",\"persona\":\"{}\",\"alg\":\"{}\",\
                     \"k\":{},\"label\":\"{}\",\"from\":{},\"avg_us\":{}}}",
                    t.op.name(),
                    t.persona.key(),
                    esc(&b.alg),
                    b.k,
                    esc(&alg.label()),
                    b.from,
                    b.avg_us,
                );
                froms.push(b.from);
                lines.push(format!("{item}\n"));
                items.push(item);
            }
            pairs.push((key, CompiledTable { froms, lines, items }));
        }
        pairs.sort_by(|a, b| a.0.cmp(&b.0));
        let (keys, tables) = pairs.into_iter().unzip();
        Ok(Snapshot { keys, tables, generation })
    }

    /// Number of compiled tables.
    pub fn tables(&self) -> usize {
        self.keys.len()
    }

    /// Monotone reload counter (1 for the initially loaded book).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    #[inline]
    fn lookup(&self, q: &Query) -> Option<(usize, usize)> {
        let ti = self.keys.binary_search(&slot_key(q)).ok()?;
        Some((ti, self.tables[ti].pick_idx(q.count)))
    }

    /// The full response line (trailing newline) for `q`, if covered.
    fn line(&self, q: &Query) -> Option<&str> {
        let (ti, bi) = self.lookup(q)?;
        Some(&self.tables[ti].lines[bi])
    }

    /// The batch-element fragment (no newline) for `q`, if covered.
    fn item(&self, q: &Query) -> Option<&str> {
        let (ti, bi) = self.lookup(q)?;
        Some(&self.tables[ti].items[bi])
    }
}

/// What the transport loop should do after a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flow {
    Continue,
    /// `{"cmd":"quit"}` — close this stream.
    Quit,
}

/// The daemon: an `Arc<Snapshot>` behind an `RwLock` plus counters.
/// [`Service::respond`] is the whole protocol; the transports
/// ([`serve_lines`], [`serve_socket`]) only move lines in and out.
pub struct Service {
    snap: RwLock<Arc<Snapshot>>,
    book_path: Option<PathBuf>,
    queries: AtomicU64,
    errors: AtomicU64,
    reloads: AtomicU64,
}

impl Service {
    /// Serve an in-memory book (tests and benches). `{"cmd":"reload"}`
    /// has no path to re-read and reports an error response.
    pub fn from_book(book: &TuningBook) -> Result<Service, ServeError> {
        Ok(Service {
            snap: RwLock::new(Arc::new(Snapshot::compile(book, 1)?)),
            book_path: None,
            queries: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
        })
    }

    /// Load and compile a persisted book; `reload` re-reads this path.
    pub fn load(path: impl AsRef<Path>) -> Result<Service, ServeError> {
        let path = path.as_ref();
        let book = TuningBook::load(path).map_err(ServeError::Book)?;
        let mut svc = Service::from_book(&book)?;
        svc.book_path = Some(path.to_path_buf());
        Ok(svc)
    }

    /// The current snapshot (an `Arc` clone: no allocation).
    pub fn snapshot(&self) -> Arc<Snapshot> {
        self.snap.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Re-read the book path and swap the compiled snapshot in,
    /// returning the new table count. The snapshot is fully built
    /// before the brief write lock; on any error the old snapshot
    /// stays installed and keeps serving.
    pub fn reload(&self) -> Result<usize, ServeError> {
        let path = self.book_path.as_deref().ok_or_else(|| {
            ServeError::Io("no book path to reload (service built from an in-memory book)".into())
        })?;
        let book = TuningBook::load(path).map_err(ServeError::Book)?;
        let generation = self.snapshot().generation() + 1;
        let snap = Arc::new(Snapshot::compile(&book, generation)?);
        let n = snap.tables();
        *self.snap.write().unwrap_or_else(|e| e.into_inner()) = snap;
        self.reloads.fetch_add(1, Ordering::Relaxed);
        Ok(n)
    }

    fn error_response(&self, out: &mut String, msg: &str) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        out.push_str("{\"ok\":false,\"error\":\"");
        out.push_str(&esc(msg));
        out.push_str("\"}\n");
    }

    fn uncovered(q: &Query) -> String {
        format!(
            "no table for {} on {}x{} (lanes={}) [{}]",
            q.op.name(),
            q.nodes,
            q.cores,
            q.lanes,
            q.persona.key()
        )
    }

    /// Answer one request line into `out` (caller clears the buffer).
    /// Every failure becomes an `{"ok":false,...}` response — this
    /// function cannot fail and must never panic on untrusted input.
    pub fn respond(&self, line: &str, out: &mut String) -> Flow {
        if line.trim().is_empty() {
            return Flow::Continue;
        }
        match wire::classify(line) {
            Ok(wire::Line::Query(q)) => {
                // The read guard is held across the lookup, so the
                // borrowed answer comes from one snapshot.
                let snap = self.snap.read().unwrap_or_else(|e| e.into_inner());
                match snap.line(&q) {
                    Some(text) => {
                        self.queries.fetch_add(1, Ordering::Relaxed);
                        out.push_str(text);
                    }
                    None => self.error_response(out, &Self::uncovered(&q)),
                }
                Flow::Continue
            }
            Ok(wire::Line::Batch(mut cur)) => {
                // One guard for the whole batch: a concurrent reload
                // cannot mix books inside one response.
                let snap = self.snap.read().unwrap_or_else(|e| e.into_inner());
                let start = out.len();
                out.push_str("{\"ok\":true,\"answers\":[");
                let mut n = 0u64;
                loop {
                    match wire::batch_next(&mut cur) {
                        Ok(None) => break,
                        Ok(Some(q)) => match snap.item(&q) {
                            Some(text) => {
                                if n > 0 {
                                    out.push(',');
                                }
                                out.push_str(text);
                                n += 1;
                            }
                            None => {
                                out.truncate(start);
                                let msg = format!("batch item {n}: {}", Self::uncovered(&q));
                                self.error_response(out, &msg);
                                return Flow::Continue;
                            }
                        },
                        Err(e) => {
                            out.truncate(start);
                            let err = ServeError::Request(format!("batch item {n}: {e}"));
                            self.error_response(out, &err.to_string());
                            return Flow::Continue;
                        }
                    }
                }
                out.push_str("]}\n");
                self.queries.fetch_add(n, Ordering::Relaxed);
                Flow::Continue
            }
            Ok(wire::Line::Cmd(cmd)) => self.command(cmd, out),
            Err(e) => {
                self.error_response(out, &ServeError::Request(e).to_string());
                Flow::Continue
            }
        }
    }

    fn command(&self, cmd: Cmd, out: &mut String) -> Flow {
        use std::fmt::Write as _;
        match cmd {
            Cmd::Stats => {
                let snap = self.snapshot();
                let _ = write!(
                    out,
                    "{{\"ok\":true,\"queries\":{},\"errors\":{},\"reloads\":{},\
                     \"tables\":{},\"generation\":{}}}",
                    self.queries.load(Ordering::Relaxed),
                    self.errors.load(Ordering::Relaxed),
                    self.reloads.load(Ordering::Relaxed),
                    snap.tables(),
                    snap.generation(),
                );
                out.push('\n');
                Flow::Continue
            }
            Cmd::Reload => {
                match self.reload() {
                    Ok(n) => {
                        let _ = write!(
                            out,
                            "{{\"ok\":true,\"reloaded\":true,\"tables\":{n},\"generation\":{}}}",
                            self.snapshot().generation(),
                        );
                        out.push('\n');
                    }
                    Err(e) => self.error_response(out, &e.to_string()),
                }
                Flow::Continue
            }
            Cmd::Quit => {
                out.push_str("{\"ok\":true,\"bye\":true}\n");
                Flow::Quit
            }
        }
    }

    /// One-line stats summary (the CLI prints this to stderr after a
    /// `--once` batch).
    pub fn summary(&self) -> String {
        format!(
            "served {} queries ({} errors, {} reloads) from {}",
            self.queries.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.reloads.load(Ordering::Relaxed),
            self.book_path
                .as_deref()
                .map_or_else(|| "<memory>".to_string(), |p| p.display().to_string()),
        )
    }
}

/// Serve newline-delimited requests from `input` until EOF or
/// `{"cmd":"quit"}`. The line and response buffers are reused, so the
/// warm single-query exchange stays allocation-free end to end.
pub fn serve_lines<R, W>(svc: &Service, mut input: R, mut output: W) -> Result<(), ServeError>
where
    R: std::io::BufRead,
    W: std::io::Write,
{
    let mut line = String::new();
    let mut out = String::new();
    loop {
        line.clear();
        let n = input
            .read_line(&mut line)
            .map_err(|e| ServeError::Io(format!("read request: {e}")))?;
        if n == 0 {
            return Ok(());
        }
        out.clear();
        let flow = svc.respond(&line, &mut out);
        if !out.is_empty() {
            output
                .write_all(out.as_bytes())
                .and_then(|()| output.flush())
                .map_err(|e| ServeError::Io(format!("write response: {e}")))?;
        }
        if flow == Flow::Quit {
            return Ok(());
        }
    }
}

/// Accept loop on a Unix domain socket: one thread per connection,
/// each running [`serve_lines`] against the shared service. `quit`
/// closes its own connection; the listener accepts until the process
/// exits.
#[cfg(unix)]
pub fn serve_socket(svc: &Arc<Service>, path: &Path) -> Result<(), ServeError> {
    use std::os::unix::net::UnixListener;
    // A stale socket file from a previous run would fail the bind.
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)
        .map_err(|e| ServeError::Io(format!("bind {}: {e}", path.display())))?;
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let svc = Arc::clone(svc);
        std::thread::spawn(move || {
            let Ok(reader) = stream.try_clone() else { return };
            let _ = serve_lines(&svc, std::io::BufReader::new(reader), stream);
        });
    }
    Ok(())
}

/// Poll the book file's mtime every `period` and hot-reload on change.
/// Reload failures keep the old snapshot and are visible in
/// `{"cmd":"stats"}` error counts; the watcher never kills the daemon.
pub fn watch_book(svc: Arc<Service>, period: std::time::Duration) {
    std::thread::spawn(move || {
        let Some(path) = svc.book_path.clone() else { return };
        let mtime = |p: &Path| std::fs::metadata(p).and_then(|m| m.modified()).ok();
        let mut last = mtime(&path);
        loop {
            std::thread::sleep(period);
            let now = mtime(&path);
            if now != last {
                last = now;
                if svc.reload().is_err() {
                    svc.errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    });
}

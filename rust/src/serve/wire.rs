//! Zero-allocation request scanner for the `mlane serve` hot path.
//!
//! The wire format is a strict, flat subset of JSON: one object per
//! line, string values without escape sequences, unsigned integer
//! numbers. Anything else is a malformed request — turned into an
//! error *response* by the caller, never a panic or a daemon exit.
//! Scanning borrows from the request line and produces only `Copy`
//! values, so a well-formed single query allocates nothing
//! (`rust/tests/serve_alloc.rs` pins this with the counting
//! allocator). Error messages are `String`s: only the error path
//! allocates.

use crate::algorithms::registry::OpKind;
use crate::model::PersonaName;

/// One parsed single-query request. All fields are `Copy`: building a
/// `Query` allocates nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Query {
    pub op: OpKind,
    pub persona: PersonaName,
    pub nodes: u32,
    pub cores: u32,
    pub lanes: u32,
    pub count: u64,
}

/// Daemon control commands (`{"cmd":"..."}` lines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmd {
    Reload,
    Stats,
    Quit,
}

/// How one request line should be handled.
pub enum Line<'a> {
    /// `{"op":...,"persona":...,...}` — answer one query.
    Query(Query),
    /// `{"batch":[...]}` — the cursor sits at the first element;
    /// drain it with [`batch_next`].
    Batch(Cursor<'a>),
    /// `{"cmd":"..."}`.
    Cmd(Cmd),
}

/// A byte cursor over one request line.
pub struct Cursor<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn new(line: &'a str) -> Cursor<'a> {
        Cursor { s: line.as_bytes(), i: 0 }
    }

    fn pos(&self) -> usize {
        self.i
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn bump(&mut self) {
        self.i += 1;
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\r' | b'\n')) {
            self.bump();
        }
    }

    fn eat(&mut self, want: u8) -> Result<(), String> {
        match self.peek() {
            Some(b) if b == want => {
                self.bump();
                Ok(())
            }
            other => Err(expected(want as char, other, self.i)),
        }
    }

    /// A `"…"` string without escapes, as a slice borrowed from the
    /// line. The quote bytes are ASCII, so slicing between them can
    /// never split a multi-byte character.
    fn string(&mut self) -> Result<&'a str, String> {
        self.eat(b'"')?;
        let start = self.i;
        loop {
            match self.peek() {
                Some(b'"') => break,
                Some(b'\\') => {
                    return Err(format!(
                        "escape sequences are not allowed in requests (byte {})",
                        self.i
                    ));
                }
                Some(_) => self.bump(),
                None => return Err("unterminated string".into()),
            }
        }
        let end = self.i;
        self.bump();
        std::str::from_utf8(&self.s[start..end]).map_err(|_| "non-UTF-8 string".into())
    }

    /// An unsigned decimal integer with overflow checking.
    fn uint(&mut self) -> Result<u64, String> {
        let mut v: u64 = 0;
        let mut any = false;
        while let Some(b @ b'0'..=b'9') = self.peek() {
            any = true;
            v = v
                .checked_mul(10)
                .and_then(|v| v.checked_add(u64::from(b - b'0')))
                .ok_or_else(|| format!("number overflows u64 (byte {})", self.i))?;
            self.bump();
        }
        if !any {
            return Err(format!("expected an unsigned integer at byte {}", self.i));
        }
        Ok(v)
    }

    /// Whitespace, then end of line.
    fn end(&mut self) -> Result<(), String> {
        self.ws();
        if self.i == self.s.len() {
            Ok(())
        } else {
            Err(format!("trailing data at byte {}", self.i))
        }
    }
}

fn expected(want: char, got: Option<u8>, at: usize) -> String {
    match got {
        Some(b) => format!("expected '{want}' at byte {at}, found {:?}", b as char),
        None => format!("expected '{want}' at byte {at}, found end of line"),
    }
}

/// Classify one request line. The single-query fast path borrows from
/// `line` and allocates nothing.
pub fn classify(line: &str) -> Result<Line<'_>, String> {
    let mut cur = Cursor::new(line);
    cur.ws();
    cur.eat(b'{')?;
    cur.ws();
    let key = cur.string()?;
    cur.ws();
    cur.eat(b':')?;
    cur.ws();
    match key {
        "batch" => {
            cur.eat(b'[')?;
            Ok(Line::Batch(cur))
        }
        "cmd" => {
            let cmd = match cur.string()? {
                "reload" => Cmd::Reload,
                "stats" => Cmd::Stats,
                "quit" => Cmd::Quit,
                other => return Err(format!("unknown cmd {other:?} (reload|stats|quit)")),
            };
            cur.ws();
            cur.eat(b'}')?;
            cur.end()?;
            Ok(Line::Cmd(cmd))
        }
        first => {
            let q = query_fields(&mut cur, first)?;
            cur.end()?;
            Ok(Line::Query(q))
        }
    }
}

/// The next element of a `{"batch":[...]}` line, or `Ok(None)` after
/// the closing `]}` (which also rejects trailing data).
pub fn batch_next(cur: &mut Cursor<'_>) -> Result<Option<Query>, String> {
    cur.ws();
    match cur.peek() {
        Some(b']') => {
            cur.bump();
            cur.ws();
            cur.eat(b'}')?;
            cur.end()?;
            Ok(None)
        }
        Some(b'{') => {
            cur.bump();
            cur.ws();
            let key = cur.string()?;
            cur.ws();
            cur.eat(b':')?;
            cur.ws();
            let q = query_fields(cur, key)?;
            cur.ws();
            if cur.peek() == Some(b',') {
                cur.bump();
                cur.ws();
                // A separator must introduce another element: rejects
                // trailing commas before `]`.
                if cur.peek() != Some(b'{') {
                    return Err(expected('{', cur.peek(), cur.pos()));
                }
            }
            Ok(Some(q))
        }
        other => Err(expected('{', other, cur.pos())),
    }
}

/// Cluster dimensions are u32 and at least 1 (`Cluster::new` rejects
/// degenerate shapes by panicking; the wire layer must fail first).
fn dim(cur: &mut Cursor<'_>, what: &str) -> Result<u32, String> {
    let v = cur.uint()?;
    if v == 0 {
        return Err(format!("{what} must be >= 1"));
    }
    u32::try_from(v).map_err(|_| format!("{what} overflows u32"))
}

/// The body of a query object. On entry the cursor sits on the first
/// key's value (`key` already consumed, colon too); on exit the
/// closing `}` has been consumed. Each of the six keys must appear
/// exactly once, tracked with a seen-bitmask; unknown or duplicate
/// keys are errors.
fn query_fields<'a>(cur: &mut Cursor<'a>, mut key: &'a str) -> Result<Query, String> {
    const OP: u8 = 1 << 0;
    const PERSONA: u8 = 1 << 1;
    const NODES: u8 = 1 << 2;
    const CORES: u8 = 1 << 3;
    const LANES: u8 = 1 << 4;
    const COUNT: u8 = 1 << 5;
    const ALL: u8 = OP | PERSONA | NODES | CORES | LANES | COUNT;

    let mut seen = 0u8;
    let mut op = OpKind::Bcast;
    let mut persona = PersonaName::OpenMpi;
    let (mut nodes, mut cores, mut lanes) = (0u32, 0u32, 0u32);
    let mut count = 0u64;
    loop {
        let bit = match key {
            "op" => {
                let s = cur.string()?;
                op = OpKind::parse(s).ok_or_else(|| format!("unknown op {s:?}"))?;
                OP
            }
            "persona" => {
                let s = cur.string()?;
                persona =
                    PersonaName::parse(s).ok_or_else(|| format!("unknown persona {s:?}"))?;
                PERSONA
            }
            "nodes" => {
                nodes = dim(cur, "nodes")?;
                NODES
            }
            "cores" => {
                cores = dim(cur, "cores")?;
                CORES
            }
            "lanes" => {
                lanes = dim(cur, "lanes")?;
                LANES
            }
            "count" => {
                count = cur.uint()?;
                COUNT
            }
            other => return Err(format!("unknown request key {other:?}")),
        };
        if seen & bit != 0 {
            return Err(format!("duplicate request key {key:?}"));
        }
        seen |= bit;
        cur.ws();
        match cur.peek() {
            Some(b',') => {
                cur.bump();
                cur.ws();
                key = cur.string()?;
                cur.ws();
                cur.eat(b':')?;
                cur.ws();
            }
            Some(b'}') => {
                cur.bump();
                break;
            }
            other => return Err(expected(',', other, cur.pos())),
        }
    }
    if seen != ALL {
        return Err("a query needs exactly op, persona, nodes, cores, lanes, count".into());
    }
    Ok(Query { op, persona, nodes, cores, lanes, count })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    /// A well-formed query line with one field spliced in.
    fn query_line(field: &str) -> String {
        let base = concat!(
            "{\"op\":\"bcast\",\"persona\":\"openmpi\",\"nodes\":2,",
            "\"cores\":4,\"lanes\":2,\"count\":600"
        );
        if field.is_empty() {
            format!("{base}}}")
        } else {
            format!("{base},{field}}}")
        }
    }

    fn q(line: &str) -> Query {
        match classify(line) {
            Ok(Line::Query(q)) => q,
            other => panic!("expected a query from {line:?}, got {:?}", other.err()),
        }
    }

    #[test]
    fn single_queries_scan_in_any_key_order() {
        let a = q(&query_line(""));
        let b = q(concat!(
            " { \"count\" : 600 , \"lanes\" : 2 , \"cores\" : 4 , \"nodes\" : 2 ,",
            " \"persona\" : \"openmpi\" , \"op\" : \"bcast\" } "
        ));
        assert_eq!(a, b);
        assert_eq!(a.op, OpKind::Bcast);
        assert_eq!(a.persona, PersonaName::OpenMpi);
        assert_eq!((a.nodes, a.cores, a.lanes, a.count), (2, 4, 2, 600));
    }

    #[test]
    fn malformed_queries_are_errors_not_panics() {
        let mut bad = vec![
            String::new(),
            "not json".into(),
            "{}".into(),
            "{\"op\":\"bcast\"}".into(),
            query_line("").replace("bcast", "noop"),
            query_line("").replace("openmpi", "nobody"),
            query_line("").replace("\"nodes\":2", "\"nodes\":0"),
            query_line("").replace("\"count\":600", "\"count\":-1"),
            query_line("").replace("\"count\":600", "\"count\":1.5"),
            query_line("").replace("\"count\":600", "\"count\":99999999999999999999999"),
            query_line("\"count\":2"),
            query_line("\"extra\":1"),
            format!("{} trailing", query_line("")),
            "{\"cmd\":\"explode\"}".into(),
        ];
        // Escapes are rejected wholesale, even where JSON allows them.
        bad.push(query_line("").replace("bcast", "bc\\u0061st"));
        for line in &bad {
            assert!(classify(line).is_err(), "should reject {line:?}");
        }
    }

    #[test]
    fn commands_classify() {
        assert!(matches!(classify("{\"cmd\":\"reload\"}"), Ok(Line::Cmd(Cmd::Reload))));
        assert!(matches!(classify("{\"cmd\":\"stats\"}"), Ok(Line::Cmd(Cmd::Stats))));
        assert!(matches!(classify("{\"cmd\":\"quit\"}"), Ok(Line::Cmd(Cmd::Quit))));
    }

    #[test]
    fn batches_drain_element_by_element() {
        let second = concat!(
            "{\"op\":\"scatter\",\"persona\":\"mpich\",\"nodes\":2,",
            "\"cores\":4,\"lanes\":2,\"count\":7}"
        );
        let line = format!("{{\"batch\":[{},{second}]}}", query_line(""));
        let Ok(Line::Batch(mut cur)) = classify(&line) else {
            panic!("batch should classify");
        };
        let first = batch_next(&mut cur).unwrap().unwrap();
        assert_eq!((first.op, first.count), (OpKind::Bcast, 600));
        let second = batch_next(&mut cur).unwrap().unwrap();
        assert_eq!((second.op, second.persona), (OpKind::Scatter, PersonaName::Mpich));
        assert!(batch_next(&mut cur).unwrap().is_none());

        let Ok(Line::Batch(mut empty)) = classify("{\"batch\":[]}") else {
            panic!("empty batch should classify");
        };
        assert!(batch_next(&mut empty).unwrap().is_none());

        for bad in [
            format!("{{\"batch\":[{},]}}", query_line("")),
            "{\"batch\":[1]}".into(),
            "{\"batch\":[]} trailing".into(),
        ] {
            let Ok(Line::Batch(mut cur)) = classify(&bad) else {
                panic!("{bad:?} should classify as a batch");
            };
            let mut failed = false;
            loop {
                match batch_next(&mut cur) {
                    Ok(None) => break,
                    Ok(Some(_)) => {}
                    Err(_) => {
                        failed = true;
                        break;
                    }
                }
            }
            assert!(failed, "should reject {bad:?}");
        }
    }
}

//! Paper §4.2 (Tables 8–22): broadcast on the full Hydra system —
//! k-lane (k=1..6), k-ported (k=1..6), full-lane and native MPI_Bcast,
//! for all three library personas.

mod bench_common;

fn main() {
    bench_common::run_tables("broadcast (Tables 8-22)", 8..=22);
}

//! Paper §4.1 (Tables 2–7): compute-node vs. network performance —
//! alltoall on p = 32 processes placed as N=32 single-core nodes vs one
//! 32-core node, k-ported implementation vs native MPI_Alltoall, for all
//! three library personas.

mod bench_common;

fn main() {
    bench_common::run_tables("node vs network alltoall (Tables 2-7)", 2..=7);
}

//! Engine micro-benchmarks (the §Perf targets in DESIGN.md):
//! * simulator event throughput at Hydra scale;
//! * schedule-build throughput;
//! * exec-backend wallclock on a small cluster (channels vs XLA phases).

use std::time::Instant;

use mlane::algorithms::{alltoall, bcast};
use mlane::exec::ExecRuntime;
use mlane::model::CostModel;
use mlane::runtime::XlaService;
use mlane::sim::Simulator;
use mlane::topology::Cluster;

fn main() {
    let m = CostModel::hydra_baseline();

    println!("=== simulator throughput (hydra-scale klane alltoall) ===");
    let cl = Cluster::hydra(2);
    let t0 = Instant::now();
    let s = alltoall::build(cl, 869, alltoall::AlltoallAlg::KLane);
    let t_build = t0.elapsed();
    println!("schedule build: {:.2?} ({} transfers)", t_build, s.num_transfers());

    let t0 = Instant::now();
    let sim = Simulator::new(&s, &m);
    println!("sim preprocess: {:.2?}", t0.elapsed());

    let reps = 5;
    let t0 = Instant::now();
    let mut events = 0u64;
    for rep in 0..reps {
        events += sim.run(rep as u64).events;
    }
    let dt = t0.elapsed();
    println!(
        "sim run: {:.2?} for {reps} reps, {:.2}M events/s",
        dt,
        events as f64 / dt.as_secs_f64() / 1e6
    );

    println!("\n=== simulator throughput (kported bcast, many small rounds) ===");
    let s = bcast::build(cl, 0, 100, bcast::BcastAlg::KPorted { k: 2 });
    let sim = Simulator::new(&s, &m);
    let t0 = Instant::now();
    let n = 2000;
    let mut events = 0u64;
    for rep in 0..n {
        events += sim.run(rep as u64).events;
    }
    let dt = t0.elapsed();
    println!(
        "{} runs in {:.2?}: {:.2}M events/s, {:.1}us/run",
        n,
        dt,
        events as f64 / dt.as_secs_f64() / 1e6,
        dt.as_secs_f64() * 1e6 / n as f64
    );

    println!("\n=== exec backend (4x4, klane alltoall c=1024) ===");
    let cl = Cluster::new(4, 4, 2);
    let s = alltoall::build(cl, 1024, alltoall::AlltoallAlg::KLane);
    let rt = ExecRuntime::channels();
    let rep = rt.run(&s, 10, 2).expect("exec");
    let bytes = s.offnode_bytes() + s.onnode_bytes();
    println!(
        "channels: avg={:.1}us min={:.1}us  ({:.1} MB/s effective)",
        rep.summary.avg,
        rep.summary.min,
        bytes as f64 / rep.summary.avg
    );

    if std::path::Path::new("artifacts/manifest.txt").exists() {
        let rt = ExecRuntime::with_xla(XlaService::start(std::path::Path::new("artifacts")).unwrap());
        let rep = rt.run(&s, 10, 2).expect("exec xla");
        println!(
            "xla phases: avg={:.1}us min={:.1}us  (xla_phases={})",
            rep.summary.avg, rep.summary.min, rep.xla_phases
        );
    } else {
        println!("xla phases: skipped (no artifacts)");
    }
}

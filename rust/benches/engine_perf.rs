//! Engine micro-benchmarks (the §Perf targets in DESIGN.md):
//! * simulator event throughput at Hydra scale;
//! * schedule-build throughput;
//! * sweep engine: warm-cache count sweep vs. per-cell rebuild
//!   (cold/warm cells/s, prep speedup) — emitted to `BENCH_engine.json`
//!   so future PRs can track the perf trajectory;
//! * exec-backend wallclock on a small cluster (channels vs XLA phases).

use std::time::Instant;

use mlane::algorithms::registry::OpKind;
use mlane::algorithms::{alltoall, bcast, registry};
use mlane::analysis::symbolic::entry_shapes;
use mlane::analysis::{analyze, CertArena, CertifyOptions, LintConfig};
use mlane::exec::ExecRuntime;
use mlane::harness::{
    merge_dir, run_plan_with, write_shard, Grid, Merged, Plan, RunConfig, BCAST_COUNTS,
};
use mlane::model::{CostModel, Persona, PersonaName};
use mlane::netsim::{NetSim, Scenario as NetScenario};
use mlane::runtime::XlaService;
use mlane::serve::Service;
use mlane::sim::{self, AlgId, OpShape, Simulator, SweepEngine, SweepKey};
use mlane::topology::Cluster;
use mlane::tuning::{self, Scenario, TuneConfig, TuningBook};
use mlane::util::allocs::thread_allocations;

fn main() {
    let m = CostModel::hydra_baseline();

    println!("=== simulator throughput (hydra-scale klane alltoall) ===");
    let cl = Cluster::hydra(2);
    let t0 = Instant::now();
    let s = alltoall::build(cl, 869, alltoall::AlltoallAlg::KLane);
    let t_build = t0.elapsed();
    println!("schedule build: {:.2?} ({} transfers)", t_build, s.num_transfers());

    let t0 = Instant::now();
    let sim = Simulator::new(&s, &m);
    println!("sim preprocess: {:.2?}", t0.elapsed());

    let reps = 5;
    let t0 = Instant::now();
    let mut events = 0u64;
    let mut st = sim.new_state();
    for rep in 0..reps {
        events += sim.run_into(&mut st, rep as u64).events;
    }
    let dt = t0.elapsed();
    let events_per_s = events as f64 / dt.as_secs_f64();
    println!(
        "sim run: {:.2?} for {reps} reps, {:.2}M events/s",
        dt,
        events_per_s / 1e6
    );

    println!("\n=== simulator throughput (kported bcast, many small rounds) ===");
    let s = bcast::build(cl, 0, 100, bcast::BcastAlg::KPorted { k: 2 });
    let sim = Simulator::new(&s, &m);
    let t0 = Instant::now();
    let n = 2000;
    let mut events = 0u64;
    let mut st = sim.new_state();
    for rep in 0..n {
        events += sim.run_into(&mut st, rep as u64).events;
    }
    let dt = t0.elapsed();
    println!(
        "{} runs in {:.2?}: {:.2}M events/s, {:.1}us/run",
        n,
        dt,
        events as f64 / dt.as_secs_f64() / 1e6,
        dt.as_secs_f64() * 1e6 / n as f64
    );

    let event = bench_event(cl);
    let sweep = bench_sweep(cl);
    let series = bench_series();
    let tune = bench_tune(cl);
    let shard = bench_shard_merge();
    let lint = bench_lint(cl);
    let certify = bench_certify(cl);
    let serve = bench_serve();
    write_bench_json(events_per_s, &event, &sweep, &series, &tune, &shard, &lint, &certify, &serve);

    println!("\n=== exec backend (4x4, klane alltoall c=1024) ===");
    let cl = Cluster::new(4, 4, 2);
    let s = alltoall::build(cl, 1024, alltoall::AlltoallAlg::KLane);
    let rt = ExecRuntime::channels();
    let rep = rt.run(&s, 10, 2).expect("exec");
    let bytes = s.offnode_bytes() + s.onnode_bytes();
    println!(
        "channels: avg={:.1}us min={:.1}us  ({:.1} MB/s effective)",
        rep.summary.avg,
        rep.summary.min,
        bytes as f64 / rep.summary.avg
    );

    if std::path::Path::new("artifacts/manifest.txt").exists() {
        let svc = XlaService::start(std::path::Path::new("artifacts")).unwrap();
        let rt = ExecRuntime::with_xla(svc);
        let rep = rt.run(&s, 10, 2).expect("exec xla");
        println!(
            "xla phases: avg={:.1}us min={:.1}us  (xla_phases={})",
            rep.summary.avg, rep.summary.min, rep.xla_phases
        );
    } else {
        println!("xla phases: skipped (no artifacts)");
    }
}

struct EventBench {
    event_s: f64,
    events_per_s: f64,
}

/// Event-backend throughput at Hydra scale: the discrete-event
/// counterpart of the analytic number above, on the same k-lane bcast
/// family (contention-free, so the two are modeling the same physics).
/// State is allocated once and reused across reps — the same shape the
/// sweep path uses — so the number is the event loop, not the setup.
fn bench_event(cl: Cluster) -> EventBench {
    println!("\n=== event backend throughput (hydra klane bcast, contention-free) ===");
    let m = CostModel::hydra_baseline();
    let s = bcast::build(cl, 0, 100_000, bcast::BcastAlg::KLane { k: 2, two_phase: false });
    let net = NetSim::new(&s, &m, &NetScenario::contention_free())
        .expect("contention-free scenario is always valid");
    let mut st = net.new_state();
    let reps = 5;
    let t0 = Instant::now();
    let mut events = 0u64;
    for rep in 0..reps {
        events += net.run_into(&mut st, rep as u64).expect("contention-free run").events;
    }
    let event_s = t0.elapsed().as_secs_f64();
    let bench = EventBench { event_s, events_per_s: events as f64 / event_s };
    println!(
        "event run: {:.2?} for {reps} reps ({} transfers), {:.2}M events/s",
        std::time::Duration::from_secs_f64(bench.event_s),
        net.num_xfers(),
        bench.events_per_s / 1e6
    );
    bench
}

struct SweepBench {
    cells: usize,
    cold_s: f64,
    warm_s: f64,
    e2e_speedup: f64,
    prep_cold_s: f64,
    prep_warm_s: f64,
    prep_speedup: f64,
    schedules_built: u64,
}

/// The acceptance workload: Hydra k-lane bcast swept over the paper's
/// BCAST_COUNTS grid. "Cold" is the historical per-cell path (rebuild
/// Schedule + Simulator + RepState every cell); "warm" is the sweep
/// engine serving the same cells from one cached shape via
/// resize + recost + state reuse.
fn bench_sweep(cl: Cluster) -> SweepBench {
    println!("\n=== sweep engine: warm cache vs per-cell rebuild (hydra klane bcast) ===");
    let m = CostModel::hydra_baseline();
    let alg = bcast::BcastAlg::KLane { k: 2, two_phase: false };
    let (reps, warmup, seed) = (1usize, 0usize, 7u64);
    let counts = BCAST_COUNTS;
    let key = SweepKey {
        cluster: cl,
        op: OpShape::Bcast { root: 0 },
        alg: AlgId { family: "klane", k: 2 },
    };

    // Cold: rebuild everything per cell (what run_table did before the
    // sweep engine).
    let t0 = Instant::now();
    let mut cold_sum = 0.0;
    for &c in counts {
        let s = bcast::build(cl, 0, c, alg);
        cold_sum += sim::measure(&s, &m, reps, warmup, seed).avg;
    }
    let cold_s = t0.elapsed().as_secs_f64();

    // Warm: prime the engine with the first cell, then time the sweep.
    // (The engine is shared/thread-safe now; the bench drives it from
    // one thread with one reusable rep state, the section-worker shape.)
    let ok = |s: mlane::schedule::Schedule| Ok::<_, std::convert::Infallible>(s);
    let eng = SweepEngine::new();
    let mut st = None;
    eng.measure(key, counts[0], &m, reps, warmup, seed, &mut st, |c| {
        ok(bcast::build(cl, 0, c, alg))
    })
    .unwrap();
    let t0 = Instant::now();
    let mut warm_sum = 0.0;
    for &c in counts {
        let cell = eng
            .measure(key, c, &m, reps, warmup, seed, &mut st, |c| {
                ok(bcast::build(cl, 0, c, alg))
            })
            .unwrap();
        warm_sum += cell.summary.avg;
    }
    let warm_s = t0.elapsed().as_secs_f64();
    assert!(
        (cold_sum - warm_sum).abs() <= 1e-9 * cold_sum.abs(),
        "sweep engine diverged from per-cell rebuild: {cold_sum} vs {warm_sum}"
    );

    // Prep-only comparison: the per-cell overhead the engine removes
    // (schedule build + simulator preprocess vs resize + recost),
    // excluding the count-independent event simulation itself.
    let iters = 20usize;
    let t0 = Instant::now();
    for i in 0..iters {
        let c = counts[i % counts.len()];
        let s = bcast::build(cl, 0, c, alg);
        let fresh = Simulator::new(&s, &m);
        std::hint::black_box(fresh.num_xfers());
    }
    let prep_cold_s = t0.elapsed().as_secs_f64() / iters as f64;

    let mut s = bcast::build(cl, 0, counts[0], alg);
    let mut cached = Simulator::new(&s, &m);
    let t0 = Instant::now();
    for i in 0..iters {
        let c = counts[(i + 1) % counts.len()]; // always a different count
        s.resize_count(c);
        cached.recost(&s).expect("same structure");
        std::hint::black_box(cached.num_xfers());
    }
    let prep_warm_s = t0.elapsed().as_secs_f64() / iters as f64;

    let bench = SweepBench {
        cells: counts.len(),
        cold_s,
        warm_s,
        e2e_speedup: cold_s / warm_s,
        prep_cold_s,
        prep_warm_s,
        prep_speedup: prep_cold_s / prep_warm_s,
        schedules_built: eng.stats().schedules_built,
    };
    println!(
        "cold (rebuild/cell): {:>8.2?} for {} cells  ({:.1} cells/s)",
        std::time::Duration::from_secs_f64(bench.cold_s),
        bench.cells,
        bench.cells as f64 / bench.cold_s
    );
    println!(
        "warm (cached):       {:>8.2?} for {} cells  ({:.1} cells/s, {} schedule build{})",
        std::time::Duration::from_secs_f64(bench.warm_s),
        bench.cells,
        bench.cells as f64 / bench.warm_s,
        bench.schedules_built,
        if bench.schedules_built == 1 { "" } else { "s" }
    );
    println!(
        "per-cell prep: {:.1}us rebuild vs {:.1}us recost  => {:.1}x (target >= 10x)",
        bench.prep_cold_s * 1e6,
        bench.prep_warm_s * 1e6,
        bench.prep_speedup
    );
    println!("end-to-end sweep speedup (incl. simulation): {:.2}x", bench.e2e_speedup);
    bench
}

struct SeriesBench {
    cells: usize,
    series_s: f64,
    per_cell_s: f64,
    series_allocs: u64,
    per_cell_allocs: u64,
}

/// Batched series vs per-cell engine calls on a warm cache. The
/// workload is deliberately tiny (p = 2, one off-node transfer): the
/// simulation itself is a few hundred nanoseconds, so the measured gap
/// is the per-call overhead the series path amortizes — cache lookup,
/// slot lock, stats updates, per-call allocation. Both passes run the
/// identical cell sequence and must agree bitwise; the warm series pass
/// must allocate nothing (the same contract `tests/series_alloc.rs`
/// gates, here measured on the benchmark workload).
fn bench_series() -> SeriesBench {
    println!("\n=== sweep engine: batched series vs per-cell calls (tiny bcast) ===");
    let cl = Cluster::new(2, 1, 2);
    let m = CostModel::hydra_baseline();
    let alg = bcast::BcastAlg::Binomial;
    let (reps, warmup, seed) = (1usize, 0usize, 7u64);
    let counts: Vec<u64> = (0..1001).map(|i| BCAST_COUNTS[i % BCAST_COUNTS.len()]).collect();
    let key = SweepKey {
        cluster: cl,
        op: OpShape::Bcast { root: 0 },
        alg: AlgId { family: "binomial", k: 0 },
    };
    let build = |c| Ok::<_, std::convert::Infallible>(bcast::build(cl, 0, c, alg));

    // Per-cell: N engine calls, each resolving the cache and updating
    // stats on its own. Prime first so both sides run fully warm.
    let eng = SweepEngine::new();
    let mut st = None;
    eng.measure(key, counts[0], &m, reps, warmup, seed, &mut st, build).unwrap();
    let a0 = thread_allocations();
    let t0 = Instant::now();
    let mut per_cell_sum = 0.0;
    for &c in &counts {
        let cell = eng.measure(key, c, &m, reps, warmup, seed, &mut st, build).unwrap();
        per_cell_sum += cell.summary.avg;
    }
    let per_cell_s = t0.elapsed().as_secs_f64();
    let per_cell_allocs = thread_allocations() - a0;

    // Series: one engine call for the whole grid. The first pass sizes
    // the output buffer and rep state to their high-water marks; the
    // timed second pass repeats the identical trajectory steady-state.
    let eng = SweepEngine::new();
    let mut st = None;
    let mut out = Vec::new();
    eng.measure_series_into(key, &counts, &m, reps, warmup, seed, &mut st, &mut out, build)
        .unwrap();
    out.clear();
    let a0 = thread_allocations();
    let t0 = Instant::now();
    eng.measure_series_into(key, &counts, &m, reps, warmup, seed, &mut st, &mut out, build)
        .unwrap();
    let series_s = t0.elapsed().as_secs_f64();
    let series_allocs = thread_allocations() - a0;
    let series_sum: f64 = out.iter().map(|cell| cell.summary.avg).sum();
    assert_eq!(per_cell_sum, series_sum, "series path diverged from per-cell calls");
    assert_eq!(series_allocs, 0, "warm series must not touch the heap");

    let bench = SeriesBench {
        cells: counts.len(),
        series_s,
        per_cell_s,
        series_allocs,
        per_cell_allocs,
    };
    println!(
        "per-cell: {:>8.2?} for {} cells  ({:.0} cells/s, {} allocs)",
        std::time::Duration::from_secs_f64(bench.per_cell_s),
        bench.cells,
        bench.cells as f64 / bench.per_cell_s,
        bench.per_cell_allocs
    );
    println!(
        "series:   {:>8.2?} for {} cells  ({:.0} cells/s, {} allocs)",
        std::time::Duration::from_secs_f64(bench.series_s),
        bench.cells,
        bench.cells as f64 / bench.series_s,
        bench.series_allocs
    );
    println!(
        "series speedup: {:.2}x (target >= 3x; CI gate: >= 1x and zero series allocs)",
        bench.per_cell_s / bench.series_s
    );
    bench
}

struct TuneBench {
    tune_s: f64,
    breakpoints: usize,
}

/// Decision-table build cost at Hydra scale: one full bcast tuning
/// scenario (default candidates × BCAST_COUNTS) through a fresh engine
/// — the price `mlane tune` pays per (cluster, op, persona) and the
/// `tuned` meta-algorithm pays once per process on a cold cache.
fn bench_tune(cl: Cluster) -> TuneBench {
    println!("\n=== tuning: decision-table build (hydra bcast, default candidates) ===");
    let sc = Scenario::default_for(cl, OpKind::Bcast, PersonaName::OpenMpi);
    let cfg = TuneConfig { reps: 1, warmup: 0, seed: 7, ..TuneConfig::default() };
    let engine = std::sync::Arc::new(SweepEngine::new());
    let t0 = Instant::now();
    let table = tuning::tune_scenario(&engine, &sc, &cfg).expect("hydra bcast tunes");
    let tune_s = t0.elapsed().as_secs_f64();
    println!(
        "tuned {} counts x {} candidates in {:.2?}: {} breakpoint{}",
        sc.counts.len(),
        sc.candidates.len(),
        std::time::Duration::from_secs_f64(tune_s),
        table.entries.len(),
        if table.entries.len() == 1 { "" } else { "s" }
    );
    print!("{}", table.text());
    TuneBench { tune_s, breakpoints: table.entries.len() }
}

struct ShardBench {
    shards: u32,
    rows: usize,
    write_s: f64,
    merge_s: f64,
}

/// Multi-process sharding overhead: write a 3-shard artifact set for a
/// moderate plan and merge it back — the per-coordinator cost a
/// distributed `mlane tables` run adds on top of the simulation itself
/// (the simulation is benchmarked above; here we time only the
/// artifact path, which must stay negligible next to one table sweep).
fn bench_shard_merge() -> ShardBench {
    println!("\n=== shard artifacts: 3-shard write + merge (small bcast plan) ===");
    let grid = Grid::new()
        .cluster(Cluster::new(3, 4, 2))
        .op(OpKind::Bcast)
        .algs((1..=3).map(registry::klane).chain([registry::native()]))
        .counts(&[1, 600, 6000, 60_000]);
    let plan = Plan::new()
        .table(1, "shard bench", PersonaName::OpenMpi, &grid)
        .table(2, "shard bench b", PersonaName::Mpich, &grid);
    let cfg = RunConfig::default().reps(2).warmup(0).threads(2);
    let shards = 3u32;
    let dir = std::env::temp_dir().join("mlane_bench_shards");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("bench temp dir");

    let reports: Vec<_> = (0..shards)
        .map(|i| {
            let engine = std::sync::Arc::new(SweepEngine::new());
            run_plan_with(&engine, &plan.shard(shards, i), &cfg).expect("shard runs")
        })
        .collect();
    let t0 = Instant::now();
    for (i, report) in reports.iter().enumerate() {
        write_shard(
            dir.join(format!("shard_{i}.json")),
            &plan,
            &cfg,
            shards,
            i as u32,
            report,
        )
        .expect("shard writes");
    }
    let write_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let merged = match merge_dir(&dir).expect("shards merge") {
        Merged::Report(r) => r,
        Merged::Book(_) => unreachable!("plan shards"),
    };
    let merge_s = t0.elapsed().as_secs_f64();
    let rows: usize = merged.tables.iter().map(|t| t.rows.len()).sum();
    // The distributed contract, kept honest in the bench too.
    let single = run_plan_with(&std::sync::Arc::new(SweepEngine::new()), &plan, &cfg)
        .expect("single run");
    assert_eq!(merged.text(), single.text(), "merge must equal the single-process run");
    println!(
        "wrote {shards} shards in {:.2?}, merged {rows} rows in {:.2?}",
        std::time::Duration::from_secs_f64(write_s),
        std::time::Duration::from_secs_f64(merge_s)
    );
    ShardBench { shards, rows, write_s, merge_s }
}

struct LintBench {
    schedules: usize,
    diags: usize,
    lint_s: f64,
}

/// Static-analysis driver cost at Hydra scale: the registry's
/// validation instances × every supported op, one `analyze` call per
/// schedule — the `mlane lint` CI workload. Schedules are built outside
/// the timer, so the number is the analysis cost alone: one shared
/// bitset flow replay plus every pass, at p = 1152.
fn bench_lint(cl: Cluster) -> LintBench {
    println!("\n=== static analysis: full-registry lint (hydra scale) ===");
    let persona = Persona::get(PersonaName::OpenMpi);
    let count_for = |op: OpKind| match op {
        OpKind::Bcast => 64u64,
        OpKind::Scatter | OpKind::Gather => 16,
        OpKind::Allgather | OpKind::Alltoall => 8,
    };
    let mut jobs = Vec::new();
    for alg in registry::registry().validation_instances(cl) {
        if alg.name() == "tuned" {
            continue; // meta-entry: its cost is bench_tune's number
        }
        for op in OpKind::ALL {
            if !alg.supports(op) {
                continue;
            }
            let built = alg
                .build(cl, &persona, op.op(count_for(op)))
                .unwrap_or_else(|e| panic!("{} {op}: {e}", alg.label()));
            jobs.push((built.schedule, alg.ports_required(cl, op)));
        }
    }
    let t0 = Instant::now();
    let mut diags = 0usize;
    for (s, ports) in &jobs {
        let a = analyze(s, &LintConfig::new(*ports));
        assert!(a.is_clean(), "{} lints dirty at hydra scale:\n{}", s.algorithm, a.text());
        diags += a.diagnostics.len();
    }
    let lint_s = t0.elapsed().as_secs_f64();
    let bench = LintBench { schedules: jobs.len(), diags, lint_s };
    println!(
        "linted {} schedules in {:.2?} ({:.1} schedules/s, {} non-error diagnostics)",
        bench.schedules,
        std::time::Duration::from_secs_f64(bench.lint_s),
        bench.schedules as f64 / bench.lint_s,
        bench.diags
    );
    bench
}

struct CertifyBench {
    entries: usize,
    intervals: usize,
    certify_s: f64,
    steady_allocs: u64,
}

/// Symbolic certification cost at Hydra scale: `entry_shapes` (schedule
/// build + one structural pass run per structural cell) happens outside
/// the timer, so the number is the steady-state interval evaluation the
/// `mlane certify` CI job pays per certificate — exact crossover cuts
/// plus a byte-dependent deadlock replay per interval, all through one
/// reused arena. The warm loop is gated to zero allocations, the same
/// contract the unit test in `analysis::symbolic` pins.
fn bench_certify(cl: Cluster) -> CertifyBench {
    println!("\n=== symbolic certification: full-registry intervals (hydra scale) ===");
    let persona = Persona::get(PersonaName::OpenMpi);
    let opts = CertifyOptions::default();
    let mut entries = 0usize;
    let mut cells = Vec::new();
    for alg in registry::registry().validation_instances(cl) {
        if alg.name() == "tuned" {
            continue; // meta-entry: its auto-tuning cost is bench_tune's number
        }
        for op in OpKind::ALL {
            if !alg.supports(op) {
                continue;
            }
            entries += 1;
            cells.extend(
                entry_shapes(&alg, cl, &persona, op, &opts)
                    .unwrap_or_else(|e| panic!("{} {op}: {e}", alg.label())),
            );
        }
    }
    let partition = (persona.model.eager_net, persona.model.eager_shm);
    let mut arena = CertArena::new();
    let run = |arena: &mut CertArena| {
        let mut intervals = 0usize;
        for cell in &cells {
            cell.shape.eval_cells(cell.lo, cell.hi, partition, arena, &mut |_, _, out| {
                assert!(out.deadlock.is_empty(), "buffered certification deadlocked");
                intervals += 1;
            });
        }
        intervals
    };
    let intervals = run(&mut arena); // warmup: size the arena buffers once
    let reps = 10usize;
    let a0 = thread_allocations();
    let t0 = Instant::now();
    for _ in 0..reps {
        assert_eq!(run(&mut arena), intervals);
    }
    let certify_s = t0.elapsed().as_secs_f64() / reps as f64;
    let steady_allocs = thread_allocations() - a0;
    assert_eq!(steady_allocs, 0, "warm certification must not touch the heap");
    let bench = CertifyBench { entries, intervals, certify_s, steady_allocs };
    println!(
        "certified {} entries / {} intervals in {:.2?} ({:.1} intervals/s, {} allocs)",
        bench.entries,
        bench.intervals,
        std::time::Duration::from_secs_f64(bench.certify_s),
        bench.intervals as f64 / bench.certify_s,
        bench.steady_allocs
    );
    bench
}

struct ServeBench {
    queries: usize,
    serve_s: f64,
    queries_per_s: f64,
    batch_s: f64,
    batch_queries_per_s: f64,
    steady_allocs: u64,
}

/// Selection-service throughput: a compiled two-table book answering
/// prebuilt single-query lines and one 512-query batch line through
/// `Service::respond` — the transport-free hot path `mlane serve`
/// runs per request. The warm single-query loop is gated to zero
/// allocations, the same contract `tests/serve_alloc.rs` pins.
fn bench_serve() -> ServeBench {
    println!("\n=== serve: selection-service queries (tiny two-table book) ===");
    let cl = Cluster::new(2, 4, 2);
    let cfg = TuneConfig { reps: 1, warmup: 0, seed: 7, ..TuneConfig::default() };
    let engine = std::sync::Arc::new(SweepEngine::new());
    let counts = [1u64, 600, 6000, 60_000, 600_000];
    let tables = [OpKind::Bcast, OpKind::Scatter]
        .into_iter()
        .map(|op| {
            let sc = Scenario {
                cluster: cl,
                op,
                persona: PersonaName::OpenMpi,
                counts: counts.to_vec(),
                candidates: registry::registry().candidates(cl, op),
            };
            tuning::tune_scenario(&engine, &sc, &cfg).expect("tiny scenario tunes")
        })
        .collect();
    let book = TuningBook { tune: cfg, tables };
    let svc = Service::from_book(&book).expect("bench book compiles");

    // Request lines are prebuilt: the bench times answering queries,
    // not formatting them. Counts land on and around breakpoints.
    let reqs: Vec<String> = (0..64)
        .map(|i| {
            let op = if i % 2 == 0 { "bcast" } else { "scatter" };
            let c = counts[i % counts.len()].saturating_add(i as u64 % 3);
            format!(
                "{{\"op\":\"{op}\",\"persona\":\"openmpi\",\"nodes\":2,\"cores\":4,\
                 \"lanes\":2,\"count\":{c}}}"
            )
        })
        .collect();
    let batch_len = 512usize;
    let items: Vec<&str> = (0..batch_len).map(|i| reqs[i % reqs.len()].as_str()).collect();
    let batch = format!("{{\"batch\":[{}]}}", items.join(","));

    // Warm every code path and size the response buffer, then time.
    let mut out = String::new();
    for line in &reqs {
        out.clear();
        svc.respond(line, &mut out);
        assert!(out.starts_with("{\"ok\":true"), "bench queries must be covered: {out}");
    }
    let n = 200_000usize;
    let a0 = thread_allocations();
    let t0 = Instant::now();
    for i in 0..n {
        out.clear();
        svc.respond(&reqs[i % reqs.len()], &mut out);
        std::hint::black_box(out.len());
    }
    let serve_s = t0.elapsed().as_secs_f64();
    let steady_allocs = thread_allocations() - a0;
    assert_eq!(steady_allocs, 0, "warm serve queries must not touch the heap");

    out.clear();
    svc.respond(&batch, &mut out);
    assert!(out.starts_with("{\"ok\":true,\"answers\":["), "batch must be covered: {out}");
    let batch_reps = 200usize;
    let t0 = Instant::now();
    for _ in 0..batch_reps {
        out.clear();
        svc.respond(&batch, &mut out);
        std::hint::black_box(out.len());
    }
    let batch_total_s = t0.elapsed().as_secs_f64();

    let bench = ServeBench {
        queries: n,
        serve_s,
        queries_per_s: n as f64 / serve_s,
        batch_s: batch_total_s / batch_reps as f64,
        batch_queries_per_s: (batch_len * batch_reps) as f64 / batch_total_s,
        steady_allocs,
    };
    println!(
        "single: {:>8.2?} for {} queries  ({:.2}M queries/s, {} allocs)",
        std::time::Duration::from_secs_f64(bench.serve_s),
        bench.queries,
        bench.queries_per_s / 1e6,
        bench.steady_allocs
    );
    println!(
        "batch:  {:.1}us per {batch_len}-query line  ({:.2}M queries/s)",
        bench.batch_s * 1e6,
        bench.batch_queries_per_s / 1e6
    );
    bench
}

/// Machine-readable perf record for trajectory tracking across PRs.
// One record, one writer: threading every bench struct through beats
// global state, even past clippy's argument-count taste.
#[allow(clippy::too_many_arguments)]
fn write_bench_json(
    events_per_s: f64,
    event: &EventBench,
    sweep: &SweepBench,
    series: &SeriesBench,
    tune: &TuneBench,
    shard: &ShardBench,
    lint: &LintBench,
    certify: &CertifyBench,
    serve: &ServeBench,
) {
    let json = format!(
        "{{\n  \"bench\": \"engine_perf\",\n  \"events_per_s\": {:.0},\n  \
         \"sweep_cells\": {},\n  \"sweep_cold_s\": {:.6},\n  \"sweep_warm_s\": {:.6},\n  \
         \"sweep_cold_cells_per_s\": {:.2},\n  \"sweep_warm_cells_per_s\": {:.2},\n  \
         \"sweep_e2e_speedup\": {:.3},\n  \"prep_cold_us\": {:.3},\n  \
         \"prep_warm_us\": {:.3},\n  \"prep_speedup\": {:.2},\n  \
         \"schedules_built\": {},\n  \"series_cells\": {},\n  \
         \"series_s\": {:.6},\n  \"per_cell_s\": {:.6},\n  \
         \"series_cells_per_s\": {:.2},\n  \"per_cell_cells_per_s\": {:.2},\n  \
         \"series_speedup\": {:.3},\n  \"series_steady_allocs\": {},\n  \
         \"per_cell_steady_allocs\": {},\n  \"tune_scenario_s\": {:.6},\n  \
         \"tune_breakpoints\": {},\n  \"shard_count\": {},\n  \
         \"shard_rows\": {},\n  \"shard_write_s\": {:.6},\n  \
         \"shard_merge_s\": {:.6},\n  \"lint_schedules\": {},\n  \
         \"lint_diagnostics\": {},\n  \"lint_full_registry_s\": {:.6},\n  \
         \"lint_schedules_per_s\": {:.2},\n  \"certify_entries\": {},\n  \
         \"certify_intervals\": {},\n  \"certify_s\": {:.6},\n  \
         \"certify_intervals_per_s\": {:.2},\n  \"certify_steady_allocs\": {},\n  \
         \"event_backend_s\": {:.6},\n  \
         \"event_events_per_s\": {:.0},\n  \"serve_queries\": {},\n  \
         \"serve_s\": {:.6},\n  \"serve_queries_per_s\": {:.0},\n  \
         \"serve_batch_s\": {:.9},\n  \"serve_batch_queries_per_s\": {:.0},\n  \
         \"serve_steady_allocs\": {}\n}}\n",
        events_per_s,
        sweep.cells,
        sweep.cold_s,
        sweep.warm_s,
        sweep.cells as f64 / sweep.cold_s,
        sweep.cells as f64 / sweep.warm_s,
        sweep.e2e_speedup,
        sweep.prep_cold_s * 1e6,
        sweep.prep_warm_s * 1e6,
        sweep.prep_speedup,
        sweep.schedules_built,
        series.cells,
        series.series_s,
        series.per_cell_s,
        series.cells as f64 / series.series_s,
        series.cells as f64 / series.per_cell_s,
        series.per_cell_s / series.series_s,
        series.series_allocs,
        series.per_cell_allocs,
        tune.tune_s,
        tune.breakpoints,
        shard.shards,
        shard.rows,
        shard.write_s,
        shard.merge_s,
        lint.schedules,
        lint.diags,
        lint.lint_s,
        lint.schedules as f64 / lint.lint_s,
        certify.entries,
        certify.intervals,
        certify.certify_s,
        certify.intervals as f64 / certify.certify_s,
        certify.steady_allocs,
        event.event_s,
        event.events_per_s,
        serve.queries,
        serve.serve_s,
        serve.queries_per_s,
        serve.batch_s,
        serve.batch_queries_per_s,
        serve.steady_allocs,
    );
    match std::fs::write("BENCH_engine.json", &json) {
        Ok(()) => println!("wrote BENCH_engine.json"),
        Err(e) => eprintln!("BENCH_engine.json not written: {e}"),
    }
}

//! Paper §4.4 (Tables 38–49): alltoall on the full Hydra system —
//! k-lane (32 virtual lanes), k-ported (k=1..6), full-lane and native
//! MPI_Alltoall, for all three library personas.

mod bench_common;

fn main() {
    bench_common::run_tables("alltoall (Tables 38-49)", 38..=49);
}

//! Paper §4.3 (Tables 23–37): scatter on the full Hydra system —
//! k-lane (k=1..6), k-ported (k=1..6), full-lane and native MPI_Scatter,
//! for all three library personas.

mod bench_common;

fn main() {
    bench_common::run_tables("scatter (Tables 23-37)", 23..=37);
}

//! Ablation benches for the design choices DESIGN.md §5 calls out:
//!
//! 1. k-lane broadcast: full node-bcast-on-receive (the paper's
//!    implementation, §3) vs the theoretical two-phase variant
//!    (k-way bcast + final k × n/k-way fan-out).
//! 2. Alltoall: round-robin (message-size optimal) vs Bruck message
//!    combining (round optimal) — where does the crossover sit?
//! 3. Full-lane speed-up vs number of physical lanes (the §2.4
//!    question: does k lanes buy a k-fold speed-up?).
//! 4. Eager/rendezvous threshold sensitivity.

use mlane::algorithms::{allgather, alltoall, bcast};
use mlane::model::CostModel;
use mlane::sim;
use mlane::topology::Cluster;

fn quiet() -> CostModel {
    let mut m = CostModel::hydra_baseline();
    m.jitter_mean = 0.0;
    m
}

fn t(s: &mlane::schedule::Schedule, m: &CostModel) -> f64 {
    sim::measure(s, m, 3, 1, 7).avg
}

fn main() {
    let cl = Cluster::hydra(2);
    let m = quiet();

    println!("=== ablation 1: k-lane bcast, full node bcast vs two-phase ===");
    println!("{:>4} {:>10} {:>14} {:>14} {:>8}", "k", "c", "full(us)", "two-phase(us)", "ratio");
    for k in [2u32, 4, 6] {
        for c in [1000u64, 100_000, 1_000_000] {
            let full = t(&bcast::build(cl, 0, c, bcast::BcastAlg::KLane { k, two_phase: false }), &m);
            let two = t(&bcast::build(cl, 0, c, bcast::BcastAlg::KLane { k, two_phase: true }), &m);
            println!("{:>4} {:>10} {:>14.2} {:>14.2} {:>8.2}", k, c, full, two, full / two);
        }
    }

    println!("\n=== ablation 2: alltoall round-robin vs Bruck (k = 2) ===");
    println!("{:>10} {:>14} {:>14} {:>10}", "c", "roundrobin", "bruck", "winner");
    for c in [1u64, 6, 9, 53, 87, 521, 869] {
        let rr = t(&alltoall::build(cl, c, alltoall::AlltoallAlg::KPorted { k: 2 }), &m);
        let br = t(&alltoall::build(cl, c, alltoall::AlltoallAlg::Bruck { k: 2 }), &m);
        println!(
            "{:>10} {:>14.2} {:>14.2} {:>10}",
            c,
            rr,
            br,
            if br < rr { "bruck" } else { "roundrobin" }
        );
    }

    println!("\n=== ablation 3: full-lane bcast speed-up vs physical lanes ===");
    println!("{:>6} {:>14} {:>10}", "lanes", "t(us)", "speedup");
    let c = 1_000_000u64;
    let mut base = None;
    for lanes in [1u32, 2, 4, 8] {
        let mut mm = quiet();
        mm.phys_lanes = lanes;
        let s = bcast::build(Cluster::new(36, 32, lanes.min(32)), 0, c, bcast::BcastAlg::FullLane);
        let v = t(&s, &mm);
        let b = *base.get_or_insert(v);
        println!("{:>6} {:>14.2} {:>10.2}", lanes, v, b / v);
    }

    println!("\n=== ablation 4: allgather algorithm family (extension ops) ===");
    println!("{:>10} {:>12} {:>12} {:>12} {:>12}", "c", "ring", "rd", "bruck(2)", "full-lane");
    for c in [1u64, 87, 869] {
        let tt = |alg| t(&allgather::build(cl, c, alg), &m);
        println!(
            "{:>10} {:>12.2} {:>12.2} {:>12.2} {:>12.2}",
            c,
            tt(allgather::AllgatherAlg::Ring),
            tt(allgather::AllgatherAlg::RecursiveDoubling),
            tt(allgather::AllgatherAlg::Bruck { k: 2 }),
            tt(allgather::AllgatherAlg::FullLane),
        );
    }

    println!("\n=== ablation 5: eager threshold sensitivity (bcast binomial, c=1000) ===");
    println!("{:>12} {:>14}", "eager(bytes)", "t(us)");
    for eager in [0u64, 1024, 4096, 16384, 65536] {
        let mut mm = quiet();
        mm.eager_net = eager;
        let s = bcast::build(cl, 0, 1000, bcast::BcastAlg::Binomial);
        println!("{:>12} {:>14.2}", eager, t(&s, &mm));
    }
}

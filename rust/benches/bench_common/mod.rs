//! Shared driver for the table-regeneration benches (criterion is not
//! available offline; these are `harness = false` benches that both
//! *time* the regeneration and *emit* the paper-format tables + CSVs).

use std::time::Instant;

use mlane::harness::{run_table, table};

/// Repetition count for bench runs (kept modest: the simulator's jitter
/// converges quickly; override with MLANE_REPS).
pub fn bench_reps() -> String {
    std::env::var("MLANE_REPS").unwrap_or_else(|_| "5".into())
}

/// Regenerate a contiguous range of paper tables, print them, write CSVs
/// under bench_out/, and report wall time per table.
pub fn run_tables(title: &str, numbers: impl IntoIterator<Item = u32>) {
    std::env::set_var("MLANE_REPS", bench_reps());
    let dir = std::path::Path::new("bench_out");
    println!("=== {title} ===");
    let t_all = Instant::now();
    for n in numbers {
        let spec = table(n).unwrap_or_else(|| panic!("no table {n}"));
        let t0 = Instant::now();
        let out = run_table(&spec);
        let dt = t0.elapsed();
        print!("{}", out.render());
        let csv = out.write_csv(dir).expect("csv write");
        println!(
            "[bench] table {:>2} regenerated in {:>8.2?}  -> {}",
            n,
            dt,
            csv.display()
        );
    }
    println!("[bench] {title}: total {:.2?}", t_all.elapsed());
}

//! Shared driver for the table-regeneration benches (criterion is not
//! available offline; these are `harness = false` benches that both
//! *time* the regeneration and *emit* the paper-format tables + CSVs).
//!
//! Each bench is a thin plan invocation: select the paper tables in
//! range, run them as ONE plan (every section of every table drains
//! through the shared worker pool — the plan-level parallelism the
//! harness ships), then emit through the Text and Csv sinks.

use std::time::Instant;

use mlane::harness::{run_plan, CsvSink, Plan, RunConfig, TextSink};

/// Bench run configuration: environment overrides (the bench binary is
/// a CLI edge), with a modest 5-rep default — the simulator's jitter
/// converges quickly.
pub fn bench_config() -> RunConfig {
    let mut cfg = RunConfig::from_env();
    // Apply the bench default unless the env var actually overrode the
    // config (an unset, unparsable or zero MLANE_REPS does not count).
    let overridden = std::env::var("MLANE_REPS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .is_some_and(|n| n > 0);
    if !overridden {
        cfg.reps = 5;
    }
    cfg
}

/// Regenerate a contiguous range of paper tables as one plan, print
/// them, write CSVs under bench_out/, and report wall time.
pub fn run_tables(title: &str, numbers: impl IntoIterator<Item = u32>) {
    let cfg = bench_config();
    let wanted: Vec<u32> = numbers.into_iter().collect();
    let mut plan = Plan::paper();
    plan.tables.retain(|t| wanted.contains(&t.number));
    println!("=== {title} ===");
    let t0 = Instant::now();
    let report = run_plan(&plan, &cfg).expect("paper plan must run");
    let dt = t0.elapsed();
    let stdout = std::io::stdout();
    report.emit(&mut TextSink::new(stdout.lock())).expect("stdout");
    let mut csv = CsvSink::new("bench_out");
    report.emit(&mut csv).expect("csv write");
    for p in csv.written() {
        println!("[bench] csv: {}", p.display());
    }
    println!(
        "[bench] {title}: {} tables ({} sections, {} cells) in {:.2?} on {} threads",
        plan.tables.len(),
        plan.num_sections(),
        plan.num_cells(),
        dt,
        cfg.threads
    );
}
